//! `lonestar-lb` — CLI launcher for the load-balancing reproduction.
//!
//! ```text
//! lonestar-lb run      [--config F] [--suite NAME | --graph FILE | --gen SPEC]
//!                      [--algo bfs|sssp] [--strategy BS|EP|WD|NS|HP|AD|all]
//!                      [--schedule GRAN/ORDER] [--adaptive-schedules LIST]
//!                      [--adaptive-policy cost|heuristic|round-robin]
//!                      [--scale tiny|small|paper] [--seed N] [--source N]
//!                      [--xla [--artifacts DIR]] [--enforce-budget]
//!                      [--no-chunking] [--json]
//!                      [--trace-out FILE] [--metrics-out FILE] [--profile-out FILE]
//! lonestar-lb serve    [--config F] [--suite NAME | --graph FILE | --gen SPEC]
//!                      [--queries N] [--batch-size N] [--shards N]
//!                      [--devices k20c,k40,...] [--max-batch N]
//!                      [--arrival-rate Q_PER_MS] [--queue-cap N]
//!                      [--queue-policy drop|block] [--workers N]
//!                      [--fault-spec SPEC] [--deadline-ms MS]
//!                      [--max-retries N] [--retry-backoff-ms MS]
//!                      [--algo bfs|sssp|mixed] [--strategy BS|..|AD]
//!                      [--schedule GRAN/ORDER] [--adaptive-schedules LIST]
//!                      [--adaptive-policy P] [--scale S] [--seed N]
//!                      [--enforce-budget] [--verify] [--json]
//!                      [--trace-out FILE] [--metrics-out FILE] [--profile-out FILE]
//! lonestar-lb figures  [table2|fig1|fig7|fig8|fig9|fig10|fig11|figad|figserve|
//!                       figqueue|figimbalance|figavail|all]
//!                      [--scale S] [--seed N] [--out FILE.json] [--no-budget]
//! lonestar-lb generate NAME OUT [--scale S] [--seed N]
//! lonestar-lb inspect  FILE
//! lonestar-lb runtime-info [--artifacts DIR]
//! ```
//!
//! Argument parsing is hand-rolled (`Args`) — the offline build carries no
//! CLI dependency.

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::config::{parse_algo, parse_scale, ExperimentConfig, GraphSource};
use lonestar_lb::coordinator::engine::Backend;
use lonestar_lb::coordinator::run_traced;
use lonestar_lb::telemetry::{Exposition, TraceEventKind, TraceSink, DEFAULT_TRACE_CAPACITY};
use lonestar_lb::figures::{self, FigureOpts};
use lonestar_lb::graph::generators::paper_suite;
use lonestar_lb::graph::stats::DegreeStats;
use lonestar_lb::graph::{self, Graph};
use lonestar_lb::strategies::StrategyKind;
use lonestar_lb::util::Json;
use lonestar_lb::worklist::chunking::PushPolicy;
use lonestar_lb::{Error, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::sync::Arc;

/// Tiny flag parser: positionals + `--key value` + `--switch`.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

const SWITCHES: &[&str] = &[
    "xla",
    "enforce-budget",
    "no-chunking",
    "json",
    "no-budget",
    "verify",
    "help",
];

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if SWITCHES.contains(&name) {
                    flags.insert(name.to_string(), "true".to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        Error::Config(format!("flag --{name} needs a value"))
                    })?;
                    flags.insert(name.to_string(), v.clone());
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn switch(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects a number, got {v:?}"))),
        }
    }

    fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .ok()
                .filter(|x| x.is_finite() && *x >= 0.0)
                .map(Some)
                .ok_or_else(|| {
                    Error::Config(format!("--{key} expects a non-negative number, got {v:?}"))
                }),
        }
    }
}

const USAGE: &str = "usage: lonestar-lb <run|serve|figures|generate|inspect|runtime-info> [options]
  run          --suite NAME | --graph FILE | --gen SPEC | --config FILE
               --algo bfs|sssp --strategy BS|EP|WD|NS|HP|AD|all --source N
               --schedule GRAN/ORDER (composed schedule, e.g. warp/merge-path;
                 overrides --strategy)
               --adaptive-schedules LIST (comma-separated composed AD candidates)
               --adaptive-policy cost|heuristic|round-robin
               --scale tiny|small|paper --seed N
               --xla --artifacts DIR --enforce-budget --no-chunking --json
               --trace-out FILE.json --metrics-out FILE.prom --profile-out FILE.json
  serve        --suite NAME | --graph FILE | --gen SPEC | --config FILE
               --queries N --batch-size N --shards N
               --devices k20c,k40,gtx680 --max-batch N
               --arrival-rate Q_PER_MS --queue-cap N --queue-policy drop|block
               --workers N (shard worker threads; default one per shard)
               --fault-spec 'stall:shard=S,at=T,for=D;kill:...' (see serving::faults)
               --deadline-ms MS (per-query deadline; 0 = off)
               --max-retries N --retry-backoff-ms MS
               --algo bfs|sssp|mixed --strategy BS|EP|WD|NS|HP|AD
               --schedule GRAN/ORDER --adaptive-schedules LIST
               --adaptive-policy P --scale S --seed N
               --enforce-budget --verify --json
               --trace-out FILE.json --metrics-out FILE.prom --profile-out FILE.json
  figures      [table2|fig1|fig7|fig8|fig9|fig10|fig11|figad|figserve|figqueue|
                figimbalance|figavail|all]
               --scale S --seed N --out FILE.json --no-budget
  generate     NAME OUT --scale S --seed N
  inspect      FILE
  runtime-info --artifacts DIR";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = real_main(&argv) {
        eprintln!("error: {e}");
        eprintln!("{USAGE}");
        std::process::exit(1);
    }
}

fn real_main(argv: &[String]) -> Result<()> {
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..])?;
    let mut out = std::io::stdout().lock();

    match cmd {
        "run" => cmd_run(&args, &mut out),
        "serve" => cmd_serve(&args, &mut out),
        "figures" => cmd_figures(&args, &mut out),
        "generate" => cmd_generate(&args, &mut out),
        "inspect" => cmd_inspect(&args, &mut out),
        "runtime-info" => cmd_runtime_info(&args, &mut out),
        other => Err(Error::Config(format!("unknown command {other:?}"))),
    }
}

/// Resolve the `--trace-out`/`--metrics-out`/`--profile-out` destinations:
/// flags override the config file, absent everywhere means telemetry stays
/// detached.
fn trace_paths(
    args: &Args,
    cfg: &ExperimentConfig,
) -> (Option<String>, Option<String>, Option<String>) {
    (
        args.get("trace-out").map(str::to_string).or_else(|| cfg.trace_out.clone()),
        args.get("metrics-out").map(str::to_string).or_else(|| cfg.metrics_out.clone()),
        args.get("profile-out").map(str::to_string).or_else(|| cfg.profile_out.clone()),
    )
}

/// Per-kind trace-event counters as a Prometheus exposition — the `run`
/// and pre-materialized batch `serve` paths have no [`ScheduleReport`]
/// (and so no latency histograms), but their event totals are still worth
/// scraping.
fn trace_exposition(sink: &TraceSink) -> String {
    let mut exp = Exposition::new();
    for kind in TraceEventKind::ALL {
        exp.counter(
            "lonestar_trace_events_total",
            "Trace events recorded, by kind",
            &[("kind", kind.label())],
            sink.kind_count(kind) as f64,
        );
    }
    exp.counter(
        "lonestar_trace_overwritten_total",
        "Trace events lost to ring wrap-around",
        &[],
        sink.overwritten() as f64,
    );
    exp.finish()
}

/// Write the Chrome trace, metrics exposition and/or imbalance-profile
/// files. `shard_ppc` converts straggler cycles to ps in the profile
/// report (one ps-per-cycle entry per shard, indexed like
/// `shard_devices`).
fn write_trace_outputs(
    out: &mut impl Write,
    sink: &TraceSink,
    shard_devices: &[&str],
    shard_ppc: &[u64],
    trace_out: Option<&str>,
    metrics: Option<(&str, String)>,
    profile_out: Option<&str>,
) -> Result<()> {
    if let Some(path) = trace_out {
        std::fs::write(path, lonestar_lb::telemetry::chrome_trace(sink, shard_devices))?;
        writeln!(
            out,
            "wrote trace {path} ({} events, {} overwritten)",
            sink.len(),
            sink.overwritten()
        )?;
    }
    if let Some((path, text)) = metrics {
        std::fs::write(path, text)?;
        writeln!(out, "wrote metrics {path}")?;
    }
    if let Some(path) = profile_out {
        let report = lonestar_lb::telemetry::profile_report(sink, shard_ppc);
        std::fs::write(path, report.to_string())?;
        writeln!(out, "wrote profile {path}")?;
    }
    Ok(())
}

fn cmd_run(args: &Args, out: &mut impl Write) -> Result<()> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::from_file(path)?
    } else {
        let mut cfg = ExperimentConfig {
            scale: parse_scale(args.get("scale").unwrap_or("small"))?,
            seed: args.get_u64("seed", lonestar_lb::graph::generators::suite::DEFAULT_SEED)?,
            source: args.get_u64("source", 0)? as u32,
            enforce_budget: args.switch("enforce-budget"),
            push_policy: if args.switch("no-chunking") {
                PushPolicy::PerEdge
            } else {
                PushPolicy::Chunked
            },
            backend: if args.switch("xla") {
                Backend::Xla {
                    dir: args.get("artifacts").map(str::to_string),
                }
            } else {
                Backend::Native
            },
            ..Default::default()
        };
        cfg.algos = vec![parse_algo(args.get("algo").unwrap_or("sssp"))?];
        let strat = args.get("strategy").unwrap_or("all");
        cfg.strategies = if strat == "all" {
            StrategyKind::ALL_WITH_ADAPTIVE.to_vec()
        } else {
            vec![strat.parse()?]
        };
        if let Some(p) = args.get("adaptive-policy") {
            cfg.params.adaptive_policy = lonestar_lb::config::parse_adaptive_policy(p)?;
        }
        cfg.graph = if let Some(f) = args.get("graph") {
            GraphSource::File(f.to_string())
        } else if let Some(s) = args.get("suite") {
            GraphSource::Suite(s.to_string())
        } else if let Some(g) = args.get("gen") {
            GraphSource::parse(g)?
        } else {
            GraphSource::Suite("rmat16".into())
        };
        cfg
    };
    // Composed-schedule flags layer on top of either source (config file or
    // flag-built config), mirroring the `schedule`/`adaptive_schedules` keys.
    if let Some(list) = args.get("adaptive-schedules") {
        cfg.params.composed_candidates = list
            .split(',')
            .map(|s| s.trim().parse())
            .collect::<Result<_>>()?;
    }
    if let Some(spec) = args.get("schedule") {
        let sched: lonestar_lb::strategies::Schedule = spec.parse()?;
        cfg.strategies = vec![StrategyKind::Composed(sched)];
    }

    let g = Arc::new(cfg.graph.load(cfg.scale, cfg.seed)?);
    writeln!(out, "graph: {} nodes, {} edges", g.num_nodes(), g.num_edges())?;

    let (trace_out, metrics_out, profile_out) = trace_paths(args, &cfg);
    let mut sink = (trace_out.is_some() || metrics_out.is_some() || profile_out.is_some())
        .then(|| TraceSink::with_capacity(DEFAULT_TRACE_CAPACITY));
    // Successive strategy runs are laid end to end on one virtual
    // timeline, so the exported trace shows them as consecutive spans.
    let mut base_ps = 0u64;
    let mut trace_device: &'static str = "k20c";
    let mut trace_ppc: u64 = lonestar_lb::sim::DeviceSpec::k20c().ps_per_cycle();

    let mut json_rows = Vec::new();
    for rc in cfg.run_configs() {
        let dev = rc.device.clone();
        match run_traced(&g, &rc, sink.as_mut(), base_ps) {
            Ok(r) => {
                base_ps += r.metrics.total_cycles() * dev.ps_per_cycle();
                trace_device = dev.name;
                trace_ppc = dev.ps_per_cycle();
                writeln!(
                    out,
                    "{:<5} {:<4} kernel {:>10.3} ms  overhead {:>10.3} ms  total {:>10.3} ms  \
                     {:>8.2} MTEPS  iters {:>5}  launches {:>6}  host {:>7.1} ms",
                    rc.algo.name(),
                    rc.strategy.label(),
                    r.metrics.kernel_ms(&dev),
                    r.metrics.overhead_ms(&dev),
                    r.metrics.total_ms(&dev),
                    r.metrics.mteps(&dev),
                    r.metrics.iterations,
                    r.metrics.kernel_launches,
                    r.metrics.host_ns as f64 / 1e6,
                )?;
                let mut row = vec![
                    ("algo", Json::from(rc.algo.name())),
                    ("strategy", rc.strategy.label().into()),
                    ("kernel_ms", r.metrics.kernel_ms(&dev).into()),
                    ("overhead_ms", r.metrics.overhead_ms(&dev).into()),
                    ("total_ms", r.metrics.total_ms(&dev).into()),
                    ("mteps", r.metrics.mteps(&dev).into()),
                    ("iterations", r.metrics.iterations.into()),
                    ("kernel_launches", r.metrics.kernel_launches.into()),
                    ("edge_relaxations", r.metrics.edge_relaxations.into()),
                    ("peak_memory", r.metrics.peak_memory_bytes.into()),
                    ("scratch_created", r.metrics.scratch_created.into()),
                    ("scratch_reused", r.metrics.scratch_reused.into()),
                    ("scratch_peak_bytes", r.metrics.scratch_peak_bytes.into()),
                ];
                if rc.strategy.is_adaptive() {
                    row.push(("switches", r.metrics.strategy_switches.into()));
                    row.push((
                        "decision_trace",
                        Json::Arr(
                            r.metrics
                                .decisions
                                .iter()
                                .map(|d| Json::from(d.strategy))
                                .collect(),
                        ),
                    ));
                }
                json_rows.push(Json::obj(row));
            }
            Err(e) if e.is_oom() => {
                writeln!(out, "{:<5} {:<4} OOM ({e})", rc.algo.name(), rc.strategy.label())?;
                json_rows.push(Json::obj(vec![
                    ("algo", rc.algo.name().into()),
                    ("strategy", rc.strategy.label().into()),
                    ("oom", true.into()),
                ]));
            }
            Err(e) => return Err(e),
        }
    }
    if args.switch("json") {
        writeln!(out, "{}", Json::Arr(json_rows))?;
    }
    if let Some(sink) = &sink {
        let metrics = metrics_out.as_deref().map(|p| (p, trace_exposition(sink)));
        write_trace_outputs(
            out,
            sink,
            &[trace_device],
            &[trace_ppc],
            trace_out.as_deref(),
            metrics,
            profile_out.as_deref(),
        )?;
    }
    Ok(())
}

/// `serve`: the synthetic query-arrival driver over the batched serving
/// layer — `--queries` arrivals split into `--batch-size` batches, each
/// batch sharded across `--shards` simulated devices.
fn cmd_serve(args: &Args, out: &mut impl Write) -> Result<()> {
    // Flags uniformly override the config file (every flag, not a subset),
    // so `--config exp.conf --enforce-budget` means what it says.
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::from_file(path)?
    } else {
        // Defaults: small-scale rmat16 suite graph, seeded arrivals.
        ExperimentConfig::default()
    };
    if let Some(s) = args.get("scale") {
        cfg.scale = parse_scale(s)?;
    }
    if args.get("seed").is_some() {
        cfg.seed = args.get_u64("seed", cfg.seed)?;
    }
    if args.switch("enforce-budget") {
        cfg.enforce_budget = true;
    }
    if let Some(f) = args.get("graph") {
        cfg.graph = GraphSource::File(f.to_string());
    } else if let Some(s) = args.get("suite") {
        cfg.graph = GraphSource::Suite(s.to_string());
    } else if let Some(g) = args.get("gen") {
        cfg.graph = GraphSource::parse(g)?;
    }
    if let Some(b) = args.get("batch-size") {
        cfg.batch_size = lonestar_lb::config::parse_positive(b, "--batch-size")?;
    }
    if let Some(s) = args.get("shards") {
        cfg.shards = lonestar_lb::config::parse_positive(s, "--shards")?;
    }
    if let Some(d) = args.get("devices") {
        cfg.devices = lonestar_lb::config::parse_device_names(d)?;
    }
    if let Some(m) = args.get("max-batch") {
        cfg.max_batch = lonestar_lb::config::parse_positive(m, "--max-batch")?;
    }
    if let Some(rate) = args.get_f64("arrival-rate")? {
        cfg.arrival_rate = rate;
    }
    if let Some(c) = args.get("queue-cap") {
        cfg.queue_cap = lonestar_lb::config::parse_positive(c, "--queue-cap")?;
    }
    if let Some(p) = args.get("queue-policy") {
        cfg.queue_policy = lonestar_lb::serving::OverflowPolicy::parse(p)?;
    }
    if let Some(w) = args.get("workers") {
        cfg.workers = lonestar_lb::config::parse_positive(w, "--workers")?;
    }
    if let Some(f) = args.get("fault-spec") {
        cfg.fault_spec = Some(f.to_string());
    }
    if let Some(d) = args.get_f64("deadline-ms")? {
        cfg.deadline_ms = d;
    }
    if args.get("max-retries").is_some() {
        let v = args.get_u64("max-retries", cfg.max_retries as u64)?;
        cfg.max_retries = u32::try_from(v)
            .map_err(|_| Error::Config(format!("--max-retries {v} is out of range")))?;
    }
    if let Some(b) = args.get_f64("retry-backoff-ms")? {
        cfg.retry_backoff_ms = b;
    }
    if let Some(p) = args.get("adaptive-policy") {
        cfg.params.adaptive_policy = lonestar_lb::config::parse_adaptive_policy(p)?;
    }
    if let Some(list) = args.get("adaptive-schedules") {
        cfg.params.composed_candidates = list
            .split(',')
            .map(|s| s.trim().parse())
            .collect::<Result<_>>()?;
    }
    let strategy: StrategyKind = match (args.get("schedule"), args.get("strategy")) {
        // `--schedule warp/merge-path` pins every batch on one composed
        // kernel; it overrides `--strategy` the same way the config
        // `schedule` key overrides `strategies`.
        (Some(spec), _) => {
            StrategyKind::Composed(spec.parse::<lonestar_lb::strategies::Schedule>()?)
        }
        (None, Some(s)) => s.parse()?,
        (None, None) => StrategyKind::AD,
    };
    // `mixed` (the default) draws a 50/50 BFS/SSSP stream.
    let bfs_fraction = match args.get("algo").unwrap_or("mixed") {
        "mixed" => 0.5,
        other => match parse_algo(other)? {
            AlgoKind::Bfs => 1.0,
            AlgoKind::Sssp => 0.0,
        },
    };
    let total_queries = args.get_u64("queries", 32)? as usize;

    let g = Arc::new(cfg.graph.load(cfg.scale, cfg.seed)?);
    writeln!(out, "graph: {} nodes, {} edges", g.num_nodes(), g.num_edges())?;
    let devices = cfg.device_pool()?;
    let serve_cfg = lonestar_lb::serving::ServeConfig {
        strategy,
        params: cfg.params.clone(),
        enforce_budget: cfg.enforce_budget,
        devices,
        max_batch: cfg.max_batch,
        ..Default::default()
    };

    if cfg.arrival_rate > 0.0 {
        // Admission-controlled scheduler: a continuous arrival stream at
        // `--arrival-rate` queries per simulated ms against the bounded
        // queue, load-aware-placed over the (possibly heterogeneous)
        // device pool.
        return cmd_serve_stream(args, out, &g, &cfg, serve_cfg, total_queries, bfs_fraction);
    }

    writeln!(
        out,
        "serving {total_queries} queries, batch_size {}, {} shard(s) [{}], strategy {}",
        cfg.batch_size,
        serve_cfg.shards(),
        serve_cfg
            .devices
            .iter()
            .map(|d| d.name)
            .collect::<Vec<_>>()
            .join(","),
        strategy.label()
    )?;

    let queries = lonestar_lb::serving::synthetic_queries(&g, total_queries, bfs_fraction, cfg.seed);
    let (trace_out, metrics_out, profile_out) = trace_paths(args, &cfg);
    let mut sink = (trace_out.is_some() || metrics_out.is_some() || profile_out.is_some())
        .then(|| TraceSink::with_capacity(DEFAULT_TRACE_CAPACITY));
    // Batches run back-to-back on the trace timeline: each batch starts
    // where the previous batch's slowest shard finished.
    let mut base_ps = 0u64;
    let mut json_rows = Vec::new();
    let mut grand = Vec::new();
    // Batches run back-to-back, so the stream's wall-clock is the *sum* of
    // per-batch walls (each batch wall = its slowest shard, timed on that
    // shard's own device clock).
    let mut wall_ms = 0.0f64;
    let mut total_ms = 0.0f64;
    for (bi, chunk) in queries.chunks(cfg.batch_size).enumerate() {
        // A fresh cache per batch keeps the cold-start build kernels in
        // every batch's metrics, matching the untraced `serve` path.
        let report = lonestar_lb::serving::serve_traced(
            &g,
            chunk,
            &serve_cfg,
            &lonestar_lb::arena::GraphCache::new(),
            sink.as_mut(),
            base_ps,
        )?;
        base_ps += report.shards.iter().map(|s| s.busy_ps).max().unwrap_or(0);
        let totals = report.totals();
        wall_ms += report.wall_ms();
        total_ms += report.total_ms();
        writeln!(
            out,
            "batch {bi:>3}: {:>3} queries  wall {:>9.3} ms  total {:>9.3} ms  \
             inspect {:>4}  decide {:>4}  switches {:>3}",
            report.query_count(),
            report.wall_ms(),
            report.total_ms(),
            totals.inspector_passes,
            totals.policy_decisions,
            totals.strategy_switches,
        )?;
        if args.switch("verify") {
            for shard in &report.shards {
                lonestar_lb::serving::replay_single(
                    &g,
                    &shard.queries,
                    strategy,
                    &cfg.params,
                    &shard.dists,
                )?;
            }
            writeln!(out, "batch {bi:>3}: differential replay OK")?;
        }
        for shard in &report.shards {
            grand.push(shard.metrics.clone());
        }
        json_rows.push(report.to_json());
    }
    let totals = lonestar_lb::serving::aggregate(grand.iter());
    writeln!(
        out,
        "total: {} queries  wall {:.3} ms  total {:.3} ms  inspect {}  decide {}",
        queries.len(),
        wall_ms,
        total_ms,
        totals.inspector_passes,
        totals.policy_decisions,
    )?;
    if args.switch("json") {
        writeln!(out, "{}", Json::Arr(json_rows))?;
    }
    if let Some(sink) = &sink {
        let names: Vec<&str> = serve_cfg.devices.iter().map(|d| d.name).collect();
        let ppc: Vec<u64> = serve_cfg.devices.iter().map(|d| d.ps_per_cycle()).collect();
        let metrics = metrics_out.as_deref().map(|p| (p, trace_exposition(sink)));
        write_trace_outputs(
            out,
            sink,
            &names,
            &ppc,
            trace_out.as_deref(),
            metrics,
            profile_out.as_deref(),
        )?;
    }
    Ok(())
}

/// The scheduler path of `serve`: continuous seeded arrivals, bounded
/// admission queue, least-outstanding-edges placement over the device
/// pool, batches formed as capacity frees.
fn cmd_serve_stream(
    args: &Args,
    out: &mut impl Write,
    g: &Arc<lonestar_lb::graph::Csr>,
    cfg: &ExperimentConfig,
    serve_cfg: lonestar_lb::serving::ServeConfig,
    total_queries: usize,
    bfs_fraction: f64,
) -> Result<()> {
    // queries/ms → mean inter-arrival gap on the ps virtual clock.
    let mean_gap_ps = (1e9 / cfg.arrival_rate).round().max(1.0) as u64;
    writeln!(
        out,
        "scheduling {total_queries} arrivals at {} q/ms (queue cap {}, {} on overflow, \
         max_batch {}) over {} shard(s) [{}], strategy {}",
        cfg.arrival_rate,
        cfg.queue_cap,
        cfg.queue_policy.label(),
        serve_cfg.max_batch,
        serve_cfg.shards(),
        serve_cfg
            .devices
            .iter()
            .map(|d| d.name)
            .collect::<Vec<_>>()
            .join(","),
        serve_cfg.strategy.label()
    )?;
    let strategy = serve_cfg.strategy;
    let params = serve_cfg.params.clone();
    let shard_names: Vec<&str> = serve_cfg.devices.iter().map(|d| d.name).collect();
    let shard_ppc: Vec<u64> = serve_cfg.devices.iter().map(|d| d.ps_per_cycle()).collect();
    let faults = match cfg.fault_spec.as_deref() {
        Some(spec) => {
            let plan =
                lonestar_lb::serving::FaultPlan::parse(spec, serve_cfg.shards(), cfg.seed)?;
            writeln!(out, "fault plan: {} transition(s)", plan.len())?;
            (!plan.is_empty()).then_some(plan)
        }
        None => None,
    };
    let sched_cfg = lonestar_lb::serving::SchedulerConfig {
        serve: serve_cfg,
        queue_cap: cfg.queue_cap,
        overflow: cfg.queue_policy,
        collect_distances: true,
        workers: cfg.workers,
        faults,
        deadline_ps: (cfg.deadline_ms * 1e9).round() as u64,
        max_retries: cfg.max_retries,
        retry_backoff_ps: (cfg.retry_backoff_ms * 1e9).round() as u64,
    };
    let arrivals = lonestar_lb::serving::synthetic_arrivals(
        g,
        total_queries,
        bfs_fraction,
        mean_gap_ps,
        cfg.seed,
    );
    let (trace_out, metrics_out, profile_out) = trace_paths(args, cfg);
    let mut sink = (trace_out.is_some() || metrics_out.is_some() || profile_out.is_some())
        .then(|| TraceSink::with_capacity(DEFAULT_TRACE_CAPACITY));
    let cache = lonestar_lb::arena::GraphCache::new();
    let report =
        lonestar_lb::serving::serve_stream_traced(g, arrivals, &sched_cfg, &cache, sink.as_mut())?;

    for shard in &report.shards {
        writeln!(
            out,
            "shard {:>2} [{:>7}]: {:>4} queries  {:>9.3} ms on-device  util {:>5.1}%",
            shard.shard,
            shard.device.name,
            shard.queries.len(),
            shard.total_ms(),
            shard.utilization(report.wall_ps) * 100.0,
        )?;
    }
    writeln!(
        out,
        "arrived {}  admitted {}  dropped {}  served {}  queue_peak {}  batches {}",
        report.arrived,
        report.admitted,
        report.dropped.len(),
        report.served(),
        report.queue_peak,
        report.batches,
    )?;
    writeln!(
        out,
        "deadline_expired {}  failed {}  retries {}  requeued {}",
        report.deadline_expired.len(),
        report.failed.len(),
        report.retries,
        report.requeued,
    )?;
    writeln!(
        out,
        "latency ms: p50 {:.3}  p95 {:.3}  p99 {:.3}  max {:.3}  mean {:.3}  \
         wait p95 {:.3} ms  stream wall {:.3} ms",
        report.p50_latency_ms(),
        report.p95_latency_ms(),
        report.p99_latency_ms(),
        report.max_latency_ms(),
        report.mean_latency_ms(),
        report.wait_ms_p95(),
        report.wall_ms(),
    )?;
    if args.switch("verify") {
        // Served queries replay bit-identically through the single-query
        // engine; dropped queries are excluded (they were never answered)
        // but stay counted in the report above.
        for shard in &report.shards {
            lonestar_lb::serving::replay_single(g, &shard.queries, strategy, &params, &shard.dists)?;
        }
        writeln!(out, "differential replay OK ({} served)", report.served())?;
    }
    if args.switch("json") {
        writeln!(out, "{}", report.to_json())?;
    }
    if let Some(sink) = &sink {
        let metrics = metrics_out
            .as_deref()
            .map(|p| (p, report.prometheus(Some(sink))));
        write_trace_outputs(
            out,
            sink,
            &shard_names,
            &shard_ppc,
            trace_out.as_deref(),
            metrics,
            profile_out.as_deref(),
        )?;
    }
    Ok(())
}

fn cmd_figures(args: &Args, out: &mut impl Write) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let opts = FigureOpts {
        scale: parse_scale(args.get("scale").unwrap_or("small"))?,
        seed: args.get_u64("seed", lonestar_lb::graph::generators::suite::DEFAULT_SEED)?,
        enforce_budget: !args.switch("no-budget"),
        ..Default::default()
    };
    let mut payload: BTreeMap<String, Json> = BTreeMap::new();
    let all = which == "all";

    if all || which == "table2" {
        let rows = figures::table2(&opts, out)?;
        payload.insert(
            "table2".into(),
            Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
        );
    }
    if all || which == "fig1" {
        figures::fig1(&opts, out)?;
    }
    let mut sssp = None;
    let mut bfs = None;
    if all || which == "fig7" || which == "fig9" {
        let f = figures::fig7(&opts, out)?;
        payload.insert("fig7".into(), f.to_json());
        sssp = Some(f);
    }
    if all || which == "fig8" || which == "fig9" {
        let f = figures::fig8(&opts, out)?;
        payload.insert("fig8".into(), f.to_json());
        bfs = Some(f);
    }
    if all || which == "fig9" {
        let rows = figures::fig9(&opts, sssp.as_ref().unwrap(), bfs.as_ref().unwrap(), out)?;
        payload.insert(
            "fig9".into(),
            Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
        );
    }
    if all || which == "fig10" {
        let rows = figures::fig10(&opts, out)?;
        payload.insert(
            "fig10".into(),
            Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
        );
    }
    if all || which == "fig11" {
        let rows = figures::fig11(&opts, out)?;
        payload.insert(
            "fig11".into(),
            Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
        );
    }
    if all || which == "figad" || which == "adaptive" {
        let rows = figures::fig_adaptive(&opts, out)?;
        payload.insert(
            "figad".into(),
            Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
        );
    }
    if all || which == "figserve" || which == "serving" {
        let rows = figures::fig_serving(&opts, out)?;
        payload.insert(
            "figserve".into(),
            Json::Arr(rows.iter().map(|r| r.to_json(&opts.device)).collect()),
        );
    }
    if all || which == "figqueue" || which == "queue" {
        let rows = figures::fig_queue(&opts, out)?;
        payload.insert(
            "figqueue".into(),
            Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
        );
    }
    if all || which == "figimbalance" || which == "imbalance" {
        let rows = figures::fig_imbalance(&opts, out)?;
        payload.insert(
            "figimbalance".into(),
            Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
        );
    }
    if all || which == "figavail" || which == "avail" {
        let rows = figures::fig_avail(&opts, out)?;
        payload.insert(
            "figavail".into(),
            Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
        );
    }
    if payload.is_empty() && !all {
        return Err(Error::Config(format!("unknown figure {which:?}")));
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, Json::Obj(payload).to_string())?;
        writeln!(out, "\nwrote {path}")?;
    }
    Ok(())
}

fn cmd_generate(args: &Args, out: &mut impl Write) -> Result<()> {
    let name = args
        .positional
        .first()
        .ok_or_else(|| Error::Config("generate needs NAME and OUT".into()))?;
    let out_path = args
        .positional
        .get(1)
        .ok_or_else(|| Error::Config("generate needs NAME and OUT".into()))?;
    let scale = parse_scale(args.get("scale").unwrap_or("small"))?;
    let seed = args.get_u64("seed", lonestar_lb::graph::generators::suite::DEFAULT_SEED)?;
    let suite = paper_suite(scale);
    let entry = suite.iter().find(|e| e.name == *name).ok_or_else(|| {
        Error::Config(format!(
            "unknown graph {name:?}; available: {}",
            suite
                .iter()
                .map(|e| e.name.as_str())
                .collect::<Vec<_>>()
                .join(", ")
        ))
    })?;
    let g = entry.spec.generate(seed)?;
    graph::io::save(&g, out_path)?;
    writeln!(
        out,
        "wrote {} ({} nodes, {} edges)",
        out_path,
        g.num_nodes(),
        g.num_edges()
    )?;
    Ok(())
}

fn cmd_inspect(args: &Args, out: &mut impl Write) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| Error::Config("inspect needs FILE".into()))?;
    let g = graph::io::load(path)?;
    let st = DegreeStats::of(&g);
    let diam = graph::traversal::diameter_lower_bound(&g, 0);
    writeln!(out, "nodes:          {}", g.num_nodes())?;
    writeln!(out, "edges:          {}", g.num_edges())?;
    writeln!(
        out,
        "out-degree:     min {} max {} avg {:.2} sigma {:.2}",
        st.min, st.max, st.avg, st.stddev
    )?;
    writeln!(out, "imbalance:      {:.1}x (max/avg)", st.imbalance())?;
    writeln!(out, "diameter >=     {}", diam)?;
    writeln!(out, "csr bytes:      {}", g.memory_bytes())?;
    writeln!(out, "coo bytes:      {}", 12 * g.num_edges())?;
    let d = lonestar_lb::strategies::mdt::auto_mdt(&g, 10);
    writeln!(
        out,
        "auto MDT:       {} (peak bin {} of {})",
        d.mdt, d.peak_bin, d.bins
    )?;
    Ok(())
}

fn cmd_runtime_info(args: &Args, out: &mut impl Write) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let mut r = lonestar_lb::runtime::XlaRelaxer::load(dir)?;
    writeln!(out, "platform: {}", r.platform())?;
    use lonestar_lb::algorithms::Relaxer;
    let cand = r.candidates(&[0, 5, lonestar_lb::INF], &[7, 3, 1])?;
    writeln!(out, "relax([0,5,INF] + [7,3,1]) = {cand:?}")?;
    if cand != vec![7, 8, lonestar_lb::INF] {
        return Err(Error::Xla(format!("unexpected candidates {cand:?}")));
    }
    writeln!(out, "artifacts OK ({} executions)", r.executions)?;
    let _ = AlgoKind::Sssp;
    Ok(())
}
