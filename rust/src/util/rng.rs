//! Deterministic pseudo-random generator: xoshiro256** seeded via
//! SplitMix64 (Blackman & Vigna). Identical sequences across platforms and
//! runs — a hard requirement for reproducible graph generation.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (any seed, including 0, is fine).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng {
            s: [next_sm(), next_sm(), next_sm(), next_sm()],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 high bits → [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (Lemire-reduction, bias negligible for
    /// graph-generation purposes but we use rejection to be exact).
    #[inline]
    pub fn gen_range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo < hi, "empty range");
        let span = (hi - lo) as u64;
        // widening-multiply rejection sampling
        loop {
            let x = self.next_u32() as u64;
            let m = x * span;
            let l = m as u32;
            if (l as u64) >= ((u32::MAX as u64 + 1 - span) % span) {
                return lo + (m >> 32) as u32;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn gen_range_inclusive_u32(&mut self, lo: u32, hi: u32) -> u32 {
        if lo == hi {
            lo
        } else {
            self.gen_range_u32(lo, hi + 1)
        }
    }

    /// Uniform `usize` in `[0, n)`.
    #[inline]
    pub fn gen_index(&mut self, n: usize) -> usize {
        assert!(n > 0);
        self.gen_range_u32(0, u32::try_from(n).expect("index space fits u32")) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_respects_bounds() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = r.gen_range_u32(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn gen_range_hits_all_values() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.gen_range_u32(0, 8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(9);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn uniformity_chi_square_smoke() {
        // 16 buckets, 160k draws: expect ~10k each; tolerate ±5%.
        let mut r = Rng::seed_from_u64(11);
        let mut buckets = [0u32; 16];
        for _ in 0..160_000 {
            buckets[r.gen_range_u32(0, 16) as usize] += 1;
        }
        for &b in &buckets {
            assert!((9_500..10_500).contains(&b), "bucket count {b}");
        }
    }
}
