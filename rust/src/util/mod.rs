//! Zero-dependency utility substrates: deterministic RNG, JSON emission,
//! a mini property-testing harness, a bench timer, and temp-file helpers.
//!
//! The build environment is fully offline, so instead of pulling `rand`,
//! `serde`, `proptest`, `criterion` and `tempfile`, the repo carries small,
//! well-tested equivalents tailored to its needs.

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod tmp;

pub use json::Json;
pub use rng::Rng;
