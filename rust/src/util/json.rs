//! Minimal JSON: an owned value tree with an emitter and a small
//! recursive-descent parser (enough for `manifest.json` and the figure
//! harness's result files; not a general-purpose library).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Lookup a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize if an exact non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        let n = self.as_f64()?;
        if n >= 0.0 && n.fract() == 0.0 {
            Some(n as usize)
        } else {
            None
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            at: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.at != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.at));
        }
        Ok(v)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.at < self.bytes.len() && self.bytes[self.at].is_ascii_whitespace() {
            self.at += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.at).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.at += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.at,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.at)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.at..].starts_with(word.as_bytes()) {
            self.at += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.at))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.at;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.at += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.at])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.at += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.at += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.at + 1..self.at + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.at += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.at += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.at..])
                        .map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.at += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.at += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.at += 1;
                }
                Some(b']') => {
                    self.at += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] but found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.at += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.at += 1;
                }
                Some(b'}') => {
                    self.at += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} but found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let v = Json::obj(vec![
            ("name", "relax".into()),
            ("batch", 1024u32.into()),
            ("ok", true.into()),
            ("items", Json::Arr(vec![1u32.into(), 2u32.into()])),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"jax_version":"0.8.2","artifacts":[
            {"name":"relax","batch":1024,"file":"relax_b1024.hlo.txt"}]}"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("batch").unwrap().as_usize(), Some(1024));
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("relax"));
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,2,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("4.5").unwrap().as_usize(), None);
    }
}
