//! Plain-binary bench harness (a `criterion` stand-in): warmup + timed
//! iterations with mean / stddev / min reporting and optional JSON output.
//!
//! `cargo bench` runs each `benches/*.rs` binary; they call
//! [`BenchSuite::case`] per measurement and [`BenchSuite::finish`] to render
//! the table.

use std::time::Instant;

/// One measured case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// Free-form extra column (e.g. simulated ms, MTEPS).
    pub note: String,
}

/// Collects and prints bench cases.
#[derive(Debug, Default)]
pub struct BenchSuite {
    title: String,
    results: Vec<CaseResult>,
}

impl BenchSuite {
    /// New suite with a title line.
    pub fn new(title: &str) -> Self {
        println!("== bench: {title} ==");
        BenchSuite {
            title: title.to_string(),
            results: Vec::new(),
        }
    }

    /// Measure `body` (returning an optional note for the row): `warmup`
    /// unmeasured runs, then `iters` timed runs.
    pub fn case<F>(&mut self, name: &str, warmup: u32, iters: u32, mut body: F)
    where
        F: FnMut() -> String,
    {
        assert!(iters > 0);
        let mut note = String::new();
        for _ in 0..warmup {
            note = body();
        }
        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            note = body();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / samples.len() as f64;
        let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
        let r = CaseResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            min_ns: min,
            note,
        };
        println!(
            "{:<40} {:>12} {:>12} {:>12}  {}",
            r.name,
            fmt_ns(r.mean_ns),
            format!("±{}", fmt_ns(r.stddev_ns)),
            fmt_ns(r.min_ns),
            r.note
        );
        self.results.push(r);
    }

    /// Render the footer; returns the results for programmatic use.
    pub fn finish(self) -> Vec<CaseResult> {
        println!("== {} cases in {:?} ==", self.results.len(), self.title);
        self.results
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value (std::hint wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_collects_stats() {
        let mut s = BenchSuite::new("test");
        s.case("noop", 1, 5, || {
            black_box(1 + 1);
            "ok".into()
        });
        let rs = s.finish();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].iters, 5);
        assert!(rs[0].mean_ns >= 0.0);
        assert!(rs[0].min_ns <= rs[0].mean_ns);
        assert_eq!(rs[0].note, "ok");
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("us"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
