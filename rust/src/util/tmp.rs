//! Self-cleaning temp files/dirs for tests (a `tempfile` stand-in).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A file path removed on drop.
#[derive(Debug)]
pub struct TempPath {
    path: PathBuf,
    is_dir: bool,
}

impl TempPath {
    /// Unique path (not yet created) under the system temp dir with the
    /// given suffix.
    pub fn file(suffix: &str) -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "lonestar-lb-{}-{}-{}{}",
            std::process::id(),
            n,
            nanos(),
            suffix
        ));
        TempPath {
            path,
            is_dir: false,
        }
    }

    /// Unique created directory under the system temp dir.
    pub fn dir() -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "lonestar-lb-dir-{}-{}-{}",
            std::process::id(),
            n,
            nanos()
        ));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempPath { path, is_dir: true }
    }

    /// The path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

fn nanos() -> u128 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0)
}

impl Drop for TempPath {
    fn drop(&mut self) {
        if self.is_dir {
            let _ = std::fs::remove_dir_all(&self.path);
        } else {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_paths_are_unique() {
        let a = TempPath::file(".txt");
        let b = TempPath::file(".txt");
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn dir_exists_and_cleans_up() {
        let p;
        {
            let d = TempPath::dir();
            p = d.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("x"), b"hi").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn file_cleanup_on_drop() {
        let p;
        {
            let f = TempPath::file(".bin");
            p = f.path().to_path_buf();
            std::fs::write(&p, b"data").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }
}
