//! Self-cleaning temp files/dirs for tests (a `tempfile` stand-in).
//!
//! Names derive from the process id plus a process-local counter only — no
//! clock reads, so test runs are fully deterministic (the repo's tests and
//! generators route all randomness through [`crate::util::rng`] with fixed
//! seeds; this module was the last time-dependent path).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A file path removed on drop.
#[derive(Debug)]
pub struct TempPath {
    path: PathBuf,
    is_dir: bool,
}

impl TempPath {
    /// Unique path (not yet created) under the system temp dir with the
    /// given suffix.
    pub fn file(suffix: &str) -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "lonestar-lb-{}-{}{}",
            std::process::id(),
            n,
            suffix
        ));
        // pid + counter names can recur after a killed run (Drop never ran)
        // once the OS recycles the pid; clear any stale leftover so no test
        // ever reads a previous run's bytes.
        let _ = std::fs::remove_file(&path);
        TempPath {
            path,
            is_dir: false,
        }
    }

    /// Unique created directory under the system temp dir.
    pub fn dir() -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "lonestar-lb-dir-{}-{}",
            std::process::id(),
            n
        ));
        // Same stale-leftover guard as `file` (see above).
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempPath { path, is_dir: true }
    }

    /// The path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempPath {
    fn drop(&mut self) {
        if self.is_dir {
            let _ = std::fs::remove_dir_all(&self.path);
        } else {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_paths_are_unique() {
        let a = TempPath::file(".txt");
        let b = TempPath::file(".txt");
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn dir_exists_and_cleans_up() {
        let p;
        {
            let d = TempPath::dir();
            p = d.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("x"), b"hi").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn file_cleanup_on_drop() {
        let p;
        {
            let f = TempPath::file(".bin");
            p = f.path().to_path_buf();
            std::fs::write(&p, b"data").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }
}
