//! Mini property-testing harness: run a predicate over many seeded random
//! cases, reporting the failing seed for reproduction. A purpose-built
//! stand-in for `proptest` in this offline build.
//!
//! ```no_run
//! # // no_run: doctest binaries miss the libxla rpath in this environment
//! use lonestar_lb::util::proptest::forall;
//! forall("addition commutes", 100, |rng| {
//!     let a = rng.next_u32() as u64;
//!     let b = rng.next_u32() as u64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Run `body` for `cases` deterministic seeds. Panics (with the seed) on
/// the first failing case so `FORALL_SEED=<n>` reproduces it directly.
pub fn forall(name: &str, cases: u64, mut body: impl FnMut(&mut Rng)) {
    let single: Option<u64> = std::env::var("FORALL_SEED")
        .ok()
        .and_then(|s| s.parse().ok());
    let seeds: Vec<u64> = match single {
        Some(s) => vec![s],
        None => (0..cases).collect(),
    };
    for seed in seeds {
        let mut rng = Rng::seed_from_u64(0x5eed_0000 ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(payload) = result {
            eprintln!(
                "property {name:?} failed at seed {seed} (rerun with FORALL_SEED={seed})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Random small graph parameters commonly used by properties:
/// `(num_nodes in [2, max_n], num_edges in [1, max_m])`.
pub fn graph_dims(rng: &mut Rng, max_n: u32, max_m: u32) -> (usize, usize) {
    let n = rng.gen_range_u32(2, max_n + 1) as usize;
    let m = rng.gen_range_u32(1, max_m + 1) as usize;
    (n, m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall("counting", 25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn forall_is_deterministic() {
        let mut a = Vec::new();
        forall("collect-a", 5, |rng| a.push(rng.next_u64()));
        let mut b = Vec::new();
        forall("collect-b", 5, |rng| b.push(rng.next_u64()));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall("fails", 10, |rng| {
            assert!(rng.next_u64() % 2 == 0, "half the cases fail");
        });
    }
}
