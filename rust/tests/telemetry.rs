//! Telemetry integration: the trace a run records is a pure function of
//! (graph, config, seed) — byte-identical exports across runs — and the
//! event counts agree with the schedule report's own counters.

use lonestar_lb::arena::GraphCache;
use lonestar_lb::coordinator::{run_traced, RunConfig};
use lonestar_lb::graph::generators::erdos_renyi;
use lonestar_lb::serving::{
    serve_stream_traced, serve_traced, synthetic_arrivals, synthetic_queries, SchedulerConfig,
    ScheduleReport, ServeConfig,
};
use lonestar_lb::sim::DeviceSpec;
use lonestar_lb::strategies::StrategyKind;
use lonestar_lb::telemetry::{
    chrome_trace, kernel_records, profile_report, query_spans, TraceEventKind, TraceSink,
};
use lonestar_lb::util::Json;
use std::sync::Arc;

fn traced_stream(seed: u64) -> (ScheduleReport, TraceSink) {
    let g = Arc::new(erdos_renyi(512, 2048, 13, 5).unwrap());
    let arrivals = synthetic_arrivals(&g, 48, 0.5, 200_000, seed);
    let cfg = SchedulerConfig {
        serve: ServeConfig {
            strategy: StrategyKind::BS,
            devices: vec![DeviceSpec::k20c(), DeviceSpec::gtx680()],
            max_batch: 16,
            ..Default::default()
        },
        queue_cap: 12,
        ..Default::default()
    };
    let cache = GraphCache::new();
    let mut sink = TraceSink::with_capacity(1 << 15);
    let report = serve_stream_traced(&g, arrivals, &cfg, &cache, Some(&mut sink)).unwrap();
    (report, sink)
}

#[test]
fn stream_trace_is_deterministic_per_seed() {
    let (report_a, sink_a) = traced_stream(21);
    let (report_b, sink_b) = traced_stream(21);
    let trace_a = chrome_trace(&sink_a, &["k20c", "gtx680"]);
    let trace_b = chrome_trace(&sink_b, &["k20c", "gtx680"]);
    assert_eq!(trace_a, trace_b, "same seed+config must export byte-identical traces");
    assert_eq!(
        report_a.to_json().to_string(),
        report_b.to_json().to_string(),
        "report JSON must be deterministic too"
    );
    assert_eq!(
        report_a.prometheus(Some(&sink_a)),
        report_b.prometheus(Some(&sink_b))
    );

    // A different seed shifts arrival times, so the timeline differs.
    let (_, sink_c) = traced_stream(22);
    assert_ne!(
        trace_a,
        chrome_trace(&sink_c, &["k20c", "gtx680"]),
        "different seeds should not collide"
    );
}

#[test]
fn stream_trace_counts_agree_with_report() {
    let (report, sink) = traced_stream(7);
    assert_eq!(sink.overwritten(), 0, "ring must not wrap at this scale");
    assert_eq!(sink.kind_count(TraceEventKind::Arrival), report.arrived);
    assert_eq!(sink.kind_count(TraceEventKind::Admit), report.admitted);
    assert_eq!(
        sink.kind_count(TraceEventKind::Drop),
        report.dropped.len() as u64
    );
    assert_eq!(sink.kind_count(TraceEventKind::Place), report.admitted);
    assert_eq!(sink.kind_count(TraceEventKind::BatchLaunch), report.batches);
    assert_eq!(sink.kind_count(TraceEventKind::BatchComplete), report.batches);
    assert_eq!(
        sink.kind_count(TraceEventKind::ShardBusy),
        report.batches,
        "one busy slice per batch"
    );
    assert!(
        sink.kind_count(TraceEventKind::Kernel) > 0,
        "engine kernels must land in the scheduler's sink"
    );
    // Every timestamp sits inside the stream's span. (Events are recorded
    // in causal order, not timestamp order — a batch's kernel slices are
    // known at launch, before later arrivals — so only the bound holds.)
    for ev in sink.events() {
        assert!(
            ev.at_ps <= report.wall_ps,
            "{:?} at {} past wall {}",
            ev.kind,
            ev.at_ps,
            report.wall_ps
        );
    }
    // Busy intervals end by the drain instant.
    for ev in sink.events() {
        if ev.kind == TraceEventKind::ShardBusy {
            assert!(ev.at_ps + ev.a <= report.wall_ps);
        }
    }

    // The wait/latency histograms carry exactly the served population.
    assert_eq!(report.latency_hist.count(), report.served() as u64);
    assert_eq!(report.wait_hist.count(), report.served() as u64);
    assert!(report.p95_latency_ms() <= report.max_latency_ms());
    assert!(report.p50_latency_ms() <= report.p95_latency_ms());
}

#[test]
fn stream_trace_json_has_tracks_and_counters() {
    let (_, sink) = traced_stream(3);
    let trace = chrome_trace(&sink, &["k20c", "gtx680"]);
    let v = Json::parse(&trace).expect("valid json");
    let events = v.get("traceEvents").unwrap().as_arr().unwrap();
    let metas: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
        .filter_map(|e| e.get("args").unwrap().get("name").unwrap().as_str())
        .collect();
    assert!(metas.contains(&"admission/scheduler"));
    assert!(metas.contains(&"shard 0 [k20c]"));
    assert!(metas.contains(&"shard 1 [gtx680]"));
    assert!(events.iter().any(|e| {
        e.get("ph").unwrap().as_str() == Some("C")
            && e.get("name").unwrap().as_str() == Some("queue depth")
    }));
    // Slices carry non-negative µs durations.
    for e in events {
        if e.get("ph").unwrap().as_str() == Some("X") {
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        }
    }
}

#[test]
fn batch_serve_trace_lays_shards_on_one_timeline() {
    let g = Arc::new(erdos_renyi(512, 2048, 13, 5).unwrap());
    let queries = synthetic_queries(&g, 12, 0.5, 9);
    let cfg = ServeConfig {
        strategy: StrategyKind::BS,
        devices: vec![DeviceSpec::k20c(), DeviceSpec::k40()],
        max_batch: 16,
        ..Default::default()
    };
    let mut sink = TraceSink::with_capacity(1 << 14);
    let base_ps = 5_000_000;
    let report =
        serve_traced(&g, &queries, &cfg, &GraphCache::new(), Some(&mut sink), base_ps).unwrap();
    assert_eq!(report.shards.len(), 2);
    assert!(report.shards.iter().all(|s| s.busy_ps > 0));
    assert!(sink.kind_count(TraceEventKind::Kernel) > 0);
    assert!(sink.kind_count(TraceEventKind::FrontierSize) > 0);
    // Both shards' events start at the shared base instant.
    assert!(sink.events().all(|ev| ev.at_ps >= base_ps));
    let shards_seen: std::collections::BTreeSet<u32> =
        sink.events().map(|ev| ev.shard).collect();
    assert!(shards_seen.contains(&0) && shards_seen.contains(&1));

    // The traced run must not perturb the simulation: distances and
    // metrics match an untraced run exactly.
    let untraced = lonestar_lb::serving::serve(&g, &queries, &cfg).unwrap();
    for (a, b) in report.shards.iter().zip(&untraced.shards) {
        assert_eq!(a.dists, b.dists, "tracing changed results");
        assert_eq!(
            a.metrics.total_cycles(),
            b.metrics.total_cycles(),
            "tracing changed timing"
        );
    }
}

#[test]
fn every_kernel_event_carries_a_profile_companion() {
    let (_, sink) = traced_stream(7);
    assert_eq!(
        sink.kind_count(TraceEventKind::KernelProfile),
        sink.kind_count(TraceEventKind::Kernel),
        "each processing launch records exactly one profile event"
    );
    let records = kernel_records(&sink);
    assert_eq!(
        records.len() as u64,
        sink.kind_count(TraceEventKind::Kernel),
        "pairing must reconstruct every launch"
    );
    for r in &records {
        assert!(r.warps > 0, "no unpaired kernels without ring wrap");
        assert!(r.max_warp_cycles as f64 >= r.mean_warp_cycles());
        assert!(r.imbalance_factor() >= 1.0);
        assert!(r.cv >= 0.0);
        assert!((0.0..=1.0).contains(&r.occupancy), "occupancy {}", r.occupancy);
        assert!(r.dur_ps > 0, "a profiled launch occupies the timeline");
    }
}

#[test]
fn spans_cover_served_queries_and_conserve_latency() {
    let (report, sink) = traced_stream(7);
    let spans = query_spans(&sink);
    assert_eq!(
        spans.len(),
        report.served(),
        "one span per served query, dropped queries excluded"
    );
    let records = kernel_records(&sink);
    let devices = [DeviceSpec::k20c(), DeviceSpec::gtx680()];
    for s in &spans {
        assert_eq!(
            s.queue_wait_ps() + s.placement_stall_ps() + s.compute_ps(),
            s.latency_ps(),
            "decomposition must telescope exactly (query {})",
            s.query
        );
        assert!(s.arrival_ps <= s.admit_ps);
        assert!(s.admit_ps <= s.place_ps);
        assert!(s.place_ps <= s.launch_ps);
        assert!(s.launch_ps <= s.done_ps);
        // On the serving shard's own clock, imbalance attribution is a
        // slice of compute, never more.
        let ppc = devices[s.shard as usize].ps_per_cycle();
        assert!(s.imbalance_overhead_ps(&records, ppc) <= s.compute_ps());
    }
    // The latency histogram describes the same population as the spans.
    assert_eq!(report.latency_hist.count(), spans.len() as u64);
}

#[test]
fn profile_report_is_deterministic_per_seed() {
    let ppc: Vec<u64> = [DeviceSpec::k20c(), DeviceSpec::gtx680()]
        .iter()
        .map(|d| d.ps_per_cycle())
        .collect();
    let (_, sink_a) = traced_stream(21);
    let (_, sink_b) = traced_stream(21);
    let rep_a = profile_report(&sink_a, &ppc).to_string();
    let rep_b = profile_report(&sink_b, &ppc).to_string();
    assert_eq!(rep_a, rep_b, "same seed+config must export identical profiles");
    let (_, sink_c) = traced_stream(22);
    assert_ne!(
        rep_a,
        profile_report(&sink_c, &ppc).to_string(),
        "different seeds should not collide"
    );
    // Schema sanity on the parsed report.
    let v = Json::parse(&rep_a).expect("profile is valid json");
    assert_eq!(v.get("schema").unwrap().as_str(), Some("lonestar-profile-v1"));
    assert_eq!(
        v.get("span_count").unwrap().as_usize().unwrap(),
        v.get("spans").unwrap().as_arr().unwrap().len()
    );
    assert_eq!(
        v.get("batch_count").unwrap().as_usize().unwrap(),
        v.get("batches").unwrap().as_arr().unwrap().len()
    );
}

#[test]
fn run_trace_records_kernels_and_decisions() {
    let g = Arc::new(erdos_renyi(512, 2048, 13, 5).unwrap());
    let rc = RunConfig {
        strategy: StrategyKind::AD,
        ..Default::default()
    };
    let mut sink = TraceSink::with_capacity(1 << 14);
    let r = run_traced(&g, &rc, Some(&mut sink), 0).unwrap();
    assert!(r.metrics.iterations > 0);
    assert!(sink.kind_count(TraceEventKind::Kernel) > 0, "no kernel slices");
    assert_eq!(
        sink.kind_count(TraceEventKind::StrategyDecision),
        r.metrics.iterations,
        "one decision instant per adaptive iteration"
    );
    assert_eq!(
        sink.kind_count(TraceEventKind::FrontierSize),
        r.metrics.iterations
    );
    assert_eq!(
        sink.kind_count(TraceEventKind::Migration),
        r.metrics.strategy_switches,
        "migration instants mirror the switch counter"
    );
    // Kernel slices are in-bounds of the run's own span.
    let dev = rc.device.clone();
    let span = r.metrics.total_cycles() * dev.ps_per_cycle();
    for ev in sink.events() {
        if ev.kind == TraceEventKind::Kernel {
            assert!(ev.at_ps + ev.a <= span, "kernel slice past the run span");
        }
    }
}
