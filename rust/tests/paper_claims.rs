//! Integration tests asserting the paper's §IV claims — the *shape* of the
//! evaluation (who wins, roughly by what factor, where crossovers fall) on
//! the reduced-scale suite. Absolute numbers are not compared (DESIGN.md §2).

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::figures::{fig10, fig11, fig7, fig8, FigureOpts, Outcome};
use lonestar_lb::graph::generators::{paper_suite, SuiteScale};
use lonestar_lb::serving::{serve, synthetic_queries, ServeConfig};
use lonestar_lb::strategies::StrategyKind;
use lonestar_lb::worklist::chunking::PushPolicy;
use std::sync::Arc;

fn opts() -> FigureOpts {
    FigureOpts {
        scale: SuiteScale::Small,
        ..Default::default()
    }
}

fn sink() -> std::io::Sink {
    std::io::sink()
}

/// The comparison figures are expensive (full suite x 5 strategies); run
/// each once per test binary and share.
fn fig7_cached() -> &'static lonestar_lb::figures::ComparisonFigure {
    static FIG: std::sync::OnceLock<lonestar_lb::figures::ComparisonFigure> =
        std::sync::OnceLock::new();
    FIG.get_or_init(|| fig7(&opts(), &mut sink()).unwrap())
}

fn fig8_cached() -> &'static lonestar_lb::figures::ComparisonFigure {
    static FIG: std::sync::OnceLock<lonestar_lb::figures::ComparisonFigure> =
        std::sync::OnceLock::new();
    FIG.get_or_init(|| fig8(&opts(), &mut sink()).unwrap())
}

/// §IV-A: "The edge-based parallelism (EP) method performs the best, giving
/// 60-80% smaller execution times than the baseline" (SSSP), and EP cannot
/// run the Graph500 graphs.
#[test]
fn ep_dominates_sssp_where_it_fits() {
    let fig = fig7_cached();
    for row in &fig.rows {
        match row.outcome(StrategyKind::EP) {
            Outcome::Oom => {
                assert!(
                    row.graph.contains("Graph500"),
                    "{}: EP must only OOM on Graph500-class graphs",
                    row.graph
                );
            }
            Outcome::Ok { .. } => {
                let red = row.reduction_vs_bs(StrategyKind::EP).unwrap();
                assert!(
                    red >= 50.0,
                    "{}: EP reduction {red:.0}% below the paper's 60-80% band",
                    row.graph
                );
            }
        }
    }
    // EP fails on every Graph500 instance (§IV-A).
    let oom_count = fig
        .rows
        .iter()
        .filter(|r| matches!(r.outcome(StrategyKind::EP), Outcome::Oom))
        .count();
    assert_eq!(oom_count, 3, "EP must OOM on all three Graph500 graphs");
}

/// §IV-A: "workload decomposition (WD) performs the best [among node-based
/// strategies] for graphs with highly skewed or random degree distribution.
/// For such graphs (RMAT and ER), the node splitting (NS) performs the
/// worst."
#[test]
fn wd_best_and_ns_worst_node_based_on_skewed_graphs() {
    let fig = fig7_cached();
    for row in fig.rows.iter().filter(|r| {
        (r.skew_class == "skewed" || r.skew_class == "uniform")
            && !r.graph.contains("Graph500")
    }) {
        let t = |k| row.outcome(k).total_ms().unwrap();
        let node_based = [
            StrategyKind::BS,
            StrategyKind::WD,
            StrategyKind::NS,
            StrategyKind::HP,
        ];
        let wd = t(StrategyKind::WD);
        // Strict ordering on the power-law graphs; the milder ER class
        // allows a 15% tolerance (at reduced scale NS's one-time cost is
        // small enough to tie WD there).
        let slack = if row.skew_class == "skewed" { 1.0 } else { 1.15 };
        for k in node_based {
            assert!(
                wd <= t(k) * slack,
                "{}: WD ({wd:.2}ms) must be the fastest node-based strategy (vs {k}: {:.2}ms)",
                row.graph,
                t(k)
            );
        }
        if row.skew_class == "skewed" {
            // NS pays its node-creation overhead on skewed graphs: worst of
            // the *proposed* strategies (5% tolerance: NS and HP are nearly
            // tied at reduced scale, where HP's sub-iteration overhead and
            // NS's split cost shrink together).
            let ns = t(StrategyKind::NS);
            assert!(
                ns * 1.05 >= t(StrategyKind::WD) && ns * 1.05 >= t(StrategyKind::HP),
                "{}: NS must be the slowest proposed strategy on skewed graphs \
                 (NS {ns:.2} vs WD {:.2} / HP {:.2})",
                row.graph,
                t(StrategyKind::WD),
                t(StrategyKind::HP)
            );
        }
    }
}

/// §IV-A: "the main advantage of HP is seen in dealing with larger graphs…
/// we were able to execute only the HP strategy of the three load balancing
/// strategies [WD, NS, HP] for these large graphs… 48-75% reduction"
/// (our WD also completes — a documented deviation, EXPERIMENTS.md §Deviations —
/// but NS and EP hit the wall exactly as reported).
#[test]
fn hp_scales_to_graph500_with_large_gains() {
    for algo in [AlgoKind::Sssp, AlgoKind::Bfs] {
        let fig = if algo == AlgoKind::Sssp {
            fig7_cached()
        } else {
            fig8_cached()
        };
        for row in fig.rows.iter().filter(|r| r.graph.contains("Graph500")) {
            assert!(
                matches!(row.outcome(StrategyKind::NS), Outcome::Oom),
                "{}: NS must OOM (transient double-CSR rebuild)",
                row.graph
            );
            let red = row
                .reduction_vs_bs(StrategyKind::HP)
                .expect("HP must complete on Graph500");
            assert!(
                red >= 40.0,
                "{} {:?}: HP reduction {red:.0}% below the paper's 48-75% band",
                row.graph,
                algo
            );
        }
    }
}

/// §IV-A (BFS): "BFS is a memory-bound kernel… the associated overheads are
/// large in general" — on the road networks the proposed node-based
/// strategies lose to BS, unlike in SSSP.
#[test]
fn bfs_overheads_dominate_on_road_networks() {
    let fig = fig8_cached();
    for row in fig.rows.iter().filter(|r| r.skew_class == "road") {
        let bs = row.outcome(StrategyKind::BS).total_ms().unwrap();
        let wd = row.outcome(StrategyKind::WD).total_ms().unwrap();
        assert!(
            wd > bs,
            "{}: road BFS should be overhead-bound, making WD ({wd:.2}) lose to BS ({bs:.2})",
            row.graph
        );
    }
}

/// §IV-A (BFS, small diameter): "the execution time with EP is 48-68%
/// lesser than that of BS" on RMAT/ER.
#[test]
fn ep_bfs_gains_on_small_diameter_graphs() {
    let fig = fig8_cached();
    for row in fig.rows.iter().filter(|r| {
        (r.skew_class == "skewed" || r.skew_class == "uniform")
            && !r.graph.contains("Graph500")
    }) {
        let red = row.reduction_vs_bs(StrategyKind::EP).unwrap();
        assert!(
            red >= 48.0,
            "{}: EP BFS reduction {red:.0}% below the paper's 48-68% band",
            row.graph
        );
    }
}

/// §IV-C: node splitting bounds every degree by MDT, and the histogram
/// heuristic lands in the paper's reported ranges (road/ER: 2-4; RMAT:
/// ≈ maxDegree/10, i.e. 118 for max 1181).
#[test]
fn fig10_mdt_bands_and_degree_bounding() {
    let rows = fig10(&opts(), &mut sink()).unwrap();
    for r in &rows {
        assert!(r.max_after <= r.mdt, "{}: split must bound degrees", r.graph);
        if r.graph.starts_with("road") {
            assert!(
                (2..=5).contains(&r.mdt),
                "{}: road MDT {} outside the paper's band",
                r.graph,
                r.mdt
            );
        }
        if r.graph.starts_with("rmat") {
            let tenth = r.max_before / 10;
            assert!(
                r.mdt.abs_diff(tenth) <= tenth / 2 + 1,
                "{}: rmat MDT {} should be ~max/10 = {}",
                r.graph,
                r.mdt,
                tenth
            );
            // "less than 5% of the nodes undergo split"
            let frac = r.split_nodes as f64 / r.nodes_before as f64;
            assert!(frac < 0.05, "{}: {:.1}% of nodes split", r.graph, 100.0 * frac);
        }
    }
}

/// §IV-D: work chunking gives 1.11-3.125× (avg 1.82×) over per-edge appends.
#[test]
fn fig11_chunking_band() {
    let rows = fig11(&opts(), &mut sink()).unwrap();
    assert!(!rows.is_empty());
    let avg: f64 = rows.iter().map(|r| r.speedup).sum::<f64>() / rows.len() as f64;
    for r in &rows {
        assert!(
            (1.0..=4.5).contains(&r.speedup),
            "{}: chunking speedup {:.2}x outside a plausible band",
            r.graph,
            r.speedup
        );
    }
    assert!(
        (1.4..=2.6).contains(&avg),
        "average chunking speedup {avg:.2}x too far from the paper's 1.82x"
    );
}

/// §II-B / §IV-A: EP's COO arrays and NS's transient double-CSR exceed the
/// device budget on large skewed graphs — the paper's edge-based memory
/// caveat. The adaptive selector's contract is that its per-iteration
/// decision trace never *picks* a strategy whose storage cannot fit,
/// whether it drives one query or a whole serving batch.
#[test]
fn ad_trace_never_picks_memory_infeasible_strategies_batched_or_not() {
    let opts = FigureOpts {
        scale: SuiteScale::Tiny,
        ..Default::default()
    };
    for entry in paper_suite(SuiteScale::Tiny) {
        if entry.spec.skew_class() != "skewed" {
            continue; // rmat + Graph500: the paper's memory-caveat graphs
        }
        let g = Arc::new(entry.spec.generate(opts.seed).unwrap());
        let dev = opts.device_for(&entry, &g);

        // Which static strategies actually hit the wall on this graph.
        let mut infeasible = Vec::new();
        for k in [StrategyKind::EP, StrategyKind::NS] {
            let r = run(
                &g,
                &RunConfig {
                    strategy: k,
                    device: dev.clone(),
                    enforce_budget: true,
                    ..Default::default()
                },
            );
            match r {
                Err(e) if e.is_oom() => infeasible.push(k.label()),
                Err(e) => panic!("{}/{k}: {e}", entry.name),
                Ok(_) => {}
            }
        }

        // Single-query AD: completes within budget, never picking them.
        let ad = run(
            &g,
            &RunConfig {
                strategy: StrategyKind::AD,
                device: dev.clone(),
                enforce_budget: true,
                ..Default::default()
            },
        )
        .unwrap_or_else(|e| panic!("{}: AD must fit the budget: {e}", entry.name));
        assert!(ad.metrics.peak_memory_bytes <= dev.memory_budget);
        assert!(!ad.metrics.decisions.is_empty());
        for d in &ad.metrics.decisions {
            assert!(
                !infeasible.contains(&d.strategy),
                "{}: AD chose {} despite the memory caveat (infeasible: {:?})",
                entry.name,
                d.strategy,
                infeasible
            );
        }

        // Batched AD: the shared per-batch decision honours the same wall.
        let queries = synthetic_queries(&g, 3, 0.0, opts.seed);
        let report = serve(
            &g,
            &queries,
            &ServeConfig {
                devices: vec![dev.clone()],
                enforce_budget: true,
                ..Default::default()
            },
        )
        .unwrap_or_else(|e| panic!("{}: batched AD must fit the budget: {e}", entry.name));
        for shard in &report.shards {
            assert!(shard.metrics.peak_memory_bytes <= dev.memory_budget);
            for d in &shard.metrics.decisions {
                assert!(
                    !infeasible.contains(&d.strategy),
                    "{}: batched AD chose {} despite the memory caveat",
                    entry.name,
                    d.strategy
                );
            }
        }
    }
}

/// The per-edge push policy changes only *performance*, never the result.
#[test]
fn chunking_does_not_change_results() {
    let g = Arc::new(
        lonestar_lb::graph::generators::rmat(
            10,
            8 << 10,
            lonestar_lb::graph::generators::RmatParams::default(),
            5,
        )
        .unwrap(),
    );
    let base = RunConfig {
        strategy: StrategyKind::EP,
        ..Default::default()
    };
    let chunked = run(
        &g,
        &RunConfig {
            push_policy: PushPolicy::Chunked,
            ..base.clone()
        },
    )
    .unwrap();
    let per_edge = run(
        &g,
        &RunConfig {
            push_policy: PushPolicy::PerEdge,
            ..base
        },
    )
    .unwrap();
    assert_eq!(chunked.dist, per_edge.dist);
    assert!(per_edge.metrics.total_cycles() > chunked.metrics.total_cycles());
}
