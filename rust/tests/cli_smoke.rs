//! CLI smoke tests: run the built binary end-to-end over its subcommands.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_lonestar-lb"))
}

fn temp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lonestar-cli-{}-{name}", std::process::id()))
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("--help").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("usage:"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

#[test]
fn run_all_strategies_tiny() {
    let out = bin()
        .args(["run", "--suite", "rmat10", "--scale", "tiny", "--algo", "bfs"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for k in ["BS", "EP", "WD", "NS", "HP"] {
        assert!(text.contains(k), "missing {k} row:\n{text}");
    }
    assert!(text.contains("MTEPS"));
}

#[test]
fn run_emits_json() {
    let out = bin()
        .args([
            "run", "--suite", "ER10", "--scale", "tiny", "--strategy", "EP", "--json",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    let json_line = text.lines().find(|l| l.starts_with('[')).expect("json array");
    let v = lonestar_lb::util::Json::parse(json_line).expect("valid json");
    assert_eq!(
        v.as_arr().unwrap()[0].get("strategy").unwrap().as_str(),
        Some("EP")
    );
}

#[test]
fn generate_inspect_roundtrip() {
    let path = temp("road.gr");
    let out = bin()
        .args(["generate", "road-tiny", path.to_str().unwrap(), "--scale", "tiny"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin().args(["inspect", path.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("auto MDT"));
    assert!(text.contains("diameter"));
    std::fs::remove_file(&path).ok();
}

#[test]
fn run_from_generated_file_and_config() {
    let gpath = temp("er.el");
    assert!(bin()
        .args(["generate", "ER10", gpath.to_str().unwrap(), "--scale", "tiny"])
        .output()
        .unwrap()
        .status
        .success());

    // config file driving the same graph
    let cpath = temp("exp.conf");
    std::fs::write(
        &cpath,
        format!(
            "name = smoke\ngraph = file:{}\nalgos = bfs\nstrategies = BS,WD\n",
            gpath.display()
        ),
    )
    .unwrap();
    let out = bin()
        .args(["run", "--config", cpath.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("WD"));
    std::fs::remove_file(&gpath).ok();
    std::fs::remove_file(&cpath).ok();
}

#[test]
fn serve_tiny_with_verification() {
    let out = bin()
        .args([
            "serve", "--suite", "rmat10", "--scale", "tiny", "--queries", "8",
            "--batch-size", "4", "--shards", "2", "--verify", "--json",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("differential replay OK"), "no replay verdict:\n{text}");
    assert!(text.contains("inspect"), "no amortization counters:\n{text}");
    let json_line = text.lines().find(|l| l.starts_with('[')).expect("json array");
    let v = lonestar_lb::util::Json::parse(json_line).expect("valid json");
    let batches = v.as_arr().unwrap();
    assert_eq!(batches.len(), 2, "8 queries / batch_size 4 = 2 batches");
    assert_eq!(
        batches[0].get("queries").unwrap().as_usize(),
        Some(4),
        "first batch carries batch_size queries"
    );
}

#[test]
fn figures_tiny_table2() {
    let out = bin()
        .args(["figures", "table2", "--scale", "tiny"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("Table II"));
}

#[test]
fn runtime_info_works_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let out = bin().arg("runtime-info").output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("artifacts OK"));
}
