//! CLI smoke tests: run the built binary end-to-end over its subcommands.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_lonestar-lb"))
}

fn temp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lonestar-cli-{}-{name}", std::process::id()))
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("--help").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("usage:"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

#[test]
fn run_all_strategies_tiny() {
    let out = bin()
        .args(["run", "--suite", "rmat10", "--scale", "tiny", "--algo", "bfs"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for k in ["BS", "EP", "WD", "NS", "HP"] {
        assert!(text.contains(k), "missing {k} row:\n{text}");
    }
    assert!(text.contains("MTEPS"));
}

#[test]
fn run_emits_json() {
    let out = bin()
        .args([
            "run", "--suite", "ER10", "--scale", "tiny", "--strategy", "EP", "--json",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    let json_line = text.lines().find(|l| l.starts_with('[')).expect("json array");
    let v = lonestar_lb::util::Json::parse(json_line).expect("valid json");
    assert_eq!(
        v.as_arr().unwrap()[0].get("strategy").unwrap().as_str(),
        Some("EP")
    );
}

#[test]
fn generate_inspect_roundtrip() {
    let path = temp("road.gr");
    let out = bin()
        .args(["generate", "road-tiny", path.to_str().unwrap(), "--scale", "tiny"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin().args(["inspect", path.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("auto MDT"));
    assert!(text.contains("diameter"));
    std::fs::remove_file(&path).ok();
}

#[test]
fn run_from_generated_file_and_config() {
    let gpath = temp("er.el");
    assert!(bin()
        .args(["generate", "ER10", gpath.to_str().unwrap(), "--scale", "tiny"])
        .output()
        .unwrap()
        .status
        .success());

    // config file driving the same graph
    let cpath = temp("exp.conf");
    std::fs::write(
        &cpath,
        format!(
            "name = smoke\ngraph = file:{}\nalgos = bfs\nstrategies = BS,WD\n",
            gpath.display()
        ),
    )
    .unwrap();
    let out = bin()
        .args(["run", "--config", cpath.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("WD"));
    std::fs::remove_file(&gpath).ok();
    std::fs::remove_file(&cpath).ok();
}

#[test]
fn serve_tiny_with_verification() {
    let out = bin()
        .args([
            "serve", "--suite", "rmat10", "--scale", "tiny", "--queries", "8",
            "--batch-size", "4", "--shards", "2", "--verify", "--json",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("differential replay OK"), "no replay verdict:\n{text}");
    assert!(text.contains("inspect"), "no amortization counters:\n{text}");
    let json_line = text.lines().find(|l| l.starts_with('[')).expect("json array");
    let v = lonestar_lb::util::Json::parse(json_line).expect("valid json");
    let batches = v.as_arr().unwrap();
    assert_eq!(batches.len(), 2, "8 queries / batch_size 4 = 2 batches");
    assert_eq!(
        batches[0].get("queries").unwrap().as_usize(),
        Some(4),
        "first batch carries batch_size queries"
    );
}

#[test]
fn serve_scheduler_admission_control_end_to_end() {
    // The admission-controlled path: a near-simultaneous burst so the
    // queue backs up past 64 behind the two in-flight singleton batches —
    // the freed shard then forms a 70-query batch (multi-word tags) over
    // heterogeneous devices; differential verification + JSON shape.
    let out = bin()
        .args([
            "serve", "--suite", "rmat10", "--scale", "tiny", "--queries", "80",
            "--arrival-rate", "10000", "--queue-cap", "90", "--queue-policy", "drop",
            "--devices", "k20c,gtx680", "--max-batch", "70", "--verify", "--json",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("differential replay OK"),
        "no replay verdict:\n{text}"
    );
    let json_line = text.lines().find(|l| l.starts_with('{')).expect("json object");
    let v = lonestar_lb::util::Json::parse(json_line).expect("valid json");
    let arrived = v.get("arrived").unwrap().as_usize().unwrap();
    let admitted = v.get("admitted").unwrap().as_usize().unwrap();
    let dropped = v.get("dropped").unwrap().as_usize().unwrap();
    let served = v.get("served").unwrap().as_usize().unwrap();
    assert_eq!(arrived, 80);
    assert_eq!(arrived, admitted + dropped, "arrived == admitted + dropped");
    assert_eq!(admitted, served, "admitted == served at drain");
    let queue_peak = v.get("queue_peak").unwrap().as_usize().unwrap();
    // The burst outruns the first batches, so the queue must back up past
    // 64 — which with --max-batch 70 forces a multi-word (>64-query)
    // batch at the next dispatch.
    assert!(queue_peak > 64 && queue_peak <= 90, "queue_peak {queue_peak}");
    assert!(
        v.get("wait_cycles").is_none(),
        "wait_cycles is fully removed (accessor and all); read wait_ms_*"
    );
    assert!(v.get("latency_ms_mean").unwrap().as_f64().unwrap() >= 0.0);
    let shards = v.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(shards.len(), 2, "one report per device");
    assert_eq!(shards[0].get("device").unwrap().as_str(), Some("k20c"));
    assert_eq!(shards[1].get("device").unwrap().as_str(), Some("gtx680"));
    let totals = v.get("totals").unwrap();
    for key in [
        "admitted",
        "dropped",
        "queue_peak",
        "profiled_kernels",
        "imbalance_overhead_cycles",
        "mean_imbalance",
        "peak_imbalance",
    ] {
        assert!(totals.get(key).is_some(), "totals missing {key}");
    }
    assert!(
        totals.get("wait_cycles").is_none(),
        "wait_cycles stays gone from totals"
    );
}

#[test]
fn serve_trace_export_deterministic_and_shaped() {
    // The ISSUE-6 acceptance scenario: a seeded 96-query heterogeneous
    // stream exports a schema-valid Chrome trace with per-shard tracks and
    // queue-depth counters, byte-identical across two runs, and the report
    // JSON carries the histogram percentiles + per-shard utilization.
    let trace_a = temp("trace-a.json");
    let trace_b = temp("trace-b.json");
    let metrics = temp("metrics.prom");
    let serve_args = [
        "serve", "--suite", "rmat10", "--scale", "tiny", "--queries", "96",
        "--arrival-rate", "10000", "--queue-cap", "90", "--queue-policy", "drop",
        "--devices", "k20c,k40", "--max-batch", "80", "--json",
    ];
    let out = bin()
        .args(serve_args)
        .args(["--trace-out", trace_a.to_str().unwrap()])
        .args(["--metrics-out", metrics.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wrote trace"), "no trace confirmation:\n{text}");
    assert!(text.contains("wrote metrics"), "no metrics confirmation:\n{text}");

    // Report JSON: histogram-backed percentiles, monotone, plus the
    // clock-neutral waits and per-shard utilization.
    let json_line = text.lines().find(|l| l.starts_with('{')).expect("json object");
    let v = lonestar_lb::util::Json::parse(json_line).expect("valid json");
    let pick = |key: &str| -> f64 {
        v.get(key)
            .unwrap_or_else(|| panic!("missing {key}"))
            .as_f64()
            .unwrap()
    };
    let (p50, p95, p99, max) = (
        pick("latency_ms_p50"),
        pick("latency_ms_p95"),
        pick("latency_ms_p99"),
        pick("latency_ms_max"),
    );
    assert!(0.0 < p50 && p50 <= p95 && p95 <= p99 && p99 <= max, "{p50} {p95} {p99} {max}");
    assert!(pick("wait_ms_p95") >= pick("wait_ms_p50"));
    assert!(pick("wait_ms_max") >= pick("wait_ms_p95"));
    for shard in v.get("shards").unwrap().as_arr().unwrap() {
        let util = shard.get("utilization").expect("utilization").as_f64().unwrap();
        assert!((0.0..=1.0).contains(&util), "utilization {util}");
    }

    // Chrome trace: per-shard thread tracks, busy slices, queue-depth
    // counter samples.
    let trace = std::fs::read_to_string(&trace_a).unwrap();
    let tv = lonestar_lb::util::Json::parse(&trace).expect("trace is valid json");
    let events = tv.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let names: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
        .filter_map(|e| e.get("args").unwrap().get("name").unwrap().as_str())
        .collect();
    assert!(names.contains(&"shard 0 [k20c]"), "thread names: {names:?}");
    assert!(names.contains(&"shard 1 [k40]"), "thread names: {names:?}");
    assert!(
        events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("C")
            && e.get("name").unwrap().as_str() == Some("queue depth")),
        "no queue-depth counter samples"
    );
    assert!(
        events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("X")
            && e.get("name").unwrap().as_str() == Some("batch")),
        "no shard busy slices"
    );

    // Prometheus exposition: registry counters, per-shard gauges, latency
    // histogram, trace-event totals.
    let prom = std::fs::read_to_string(&metrics).unwrap();
    assert!(prom.contains("# TYPE lonestar_latency_ms histogram"), "{prom}");
    assert!(prom.contains("lonestar_latency_ms_bucket{le=\"+Inf\"}"), "{prom}");
    assert!(prom.contains("lonestar_shard_utilization{shard=\"0\",device=\"k20c\"}"));
    assert!(prom.contains("lonestar_trace_events_total{kind=\"batch-launch\"}"));
    assert!(prom.contains("lonestar_arrived_total 96\n"), "{prom}");

    // Determinism: same seed + config ⇒ byte-identical trace.
    let out = bin()
        .args(serve_args)
        .args(["--trace-out", trace_b.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(
        std::fs::read(&trace_a).unwrap(),
        std::fs::read(&trace_b).unwrap(),
        "trace export must be deterministic per seed"
    );
    std::fs::remove_file(&trace_a).ok();
    std::fs::remove_file(&trace_b).ok();
    std::fs::remove_file(&metrics).ok();
}

#[test]
fn run_trace_export_smoke() {
    // The single-query path: kernel slices + decision instants land on the
    // engine's own timeline seam.
    let trace = temp("run-trace.json");
    let out = bin()
        .args([
            "run", "--suite", "rmat10", "--scale", "tiny", "--algo", "bfs",
            "--strategy", "AD", "--trace-out", trace.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let tv = lonestar_lb::util::Json::parse(&std::fs::read_to_string(&trace).unwrap())
        .expect("trace is valid json");
    let events = tv.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(
        events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("X")
            && e.get("cat").map(|c| c.as_str()) == Some(Some("kernel"))),
        "no kernel slices in run trace"
    );
    assert!(
        events.iter().any(|e| e.get("cat").map(|c| c.as_str()) == Some(Some("decision"))),
        "no AD decision instants in run trace"
    );
    std::fs::remove_file(&trace).ok();
}

#[test]
fn run_profile_export_deterministic_and_schema_valid() {
    // --profile-out alone must attach the trace sink (no --trace-out) and
    // the report must be byte-identical across two seeded runs.
    let prof_a = temp("run-prof-a.json");
    let prof_b = temp("run-prof-b.json");
    let run_args = [
        "run", "--suite", "rmat10", "--scale", "tiny", "--algo", "sssp",
        "--strategy", "BS",
    ];
    for p in [&prof_a, &prof_b] {
        let out = bin()
            .args(run_args)
            .args(["--profile-out", p.to_str().unwrap()])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        assert!(
            String::from_utf8_lossy(&out.stdout).contains("wrote profile"),
            "no profile confirmation"
        );
    }
    assert_eq!(
        std::fs::read(&prof_a).unwrap(),
        std::fs::read(&prof_b).unwrap(),
        "profile export must be deterministic per seed"
    );
    let v = lonestar_lb::util::Json::parse(&std::fs::read_to_string(&prof_a).unwrap())
        .expect("profile is valid json");
    assert_eq!(v.get("schema").unwrap().as_str(), Some("lonestar-profile-v1"));
    assert!(
        v.get("kernel_count").unwrap().as_usize().unwrap() > 0,
        "run path must profile kernels"
    );
    // The run path has no admission lifecycle, so no spans or batches.
    assert_eq!(v.get("span_count").unwrap().as_usize(), Some(0));
    for k in v.get("kernels").unwrap().as_arr().unwrap() {
        assert!(k.get("mean_imbalance").unwrap().as_f64().unwrap() >= 0.999_999);
        let occ = k.get("mean_occupancy").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
    }
    std::fs::remove_file(&prof_a).ok();
    std::fs::remove_file(&prof_b).ok();
}

#[test]
fn serve_profile_export_spans_conserve_latency() {
    // The scheduler path: every served query gets a span whose latency
    // decomposition telescopes exactly, batches partition the served
    // population, and the export is seed-deterministic.
    let trace = temp("serve-prof-trace.json");
    let prof_a = temp("serve-prof-a.json");
    let prof_b = temp("serve-prof-b.json");
    let serve_args = [
        "serve", "--suite", "rmat10", "--scale", "tiny", "--queries", "48",
        "--arrival-rate", "8000", "--queue-cap", "40", "--queue-policy", "drop",
        "--devices", "k20c,k40", "--max-batch", "32", "--json",
    ];
    let out = bin()
        .args(serve_args)
        .args(["--trace-out", trace.to_str().unwrap()])
        .args(["--profile-out", prof_a.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wrote profile"), "no profile confirmation:\n{text}");

    let json_line = text.lines().find(|l| l.starts_with('{')).expect("json object");
    let report = lonestar_lb::util::Json::parse(json_line).expect("valid json");
    let served = report.get("served").unwrap().as_usize().unwrap();

    let v = lonestar_lb::util::Json::parse(&std::fs::read_to_string(&prof_a).unwrap())
        .expect("profile is valid json");
    assert_eq!(v.get("schema").unwrap().as_str(), Some("lonestar-profile-v1"));
    let spans = v.get("spans").unwrap().as_arr().unwrap();
    assert_eq!(spans.len(), served, "one span per served query");
    for s in spans {
        let get = |k: &str| s.get(k).unwrap().as_usize().unwrap();
        assert_eq!(
            get("queue_wait_ps") + get("placement_stall_ps") + get("compute_ps"),
            get("latency_ps"),
            "span decomposition must telescope exactly"
        );
        assert!(
            get("imbalance_overhead_ps") <= get("compute_ps"),
            "imbalance attribution cannot exceed the compute window"
        );
    }
    let widths: usize = v
        .get("batches")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|b| b.get("width").unwrap().as_usize().unwrap())
        .sum();
    assert_eq!(widths, served, "batch widths partition the served queries");

    // Second run with --profile-out only: same bytes.
    let out = bin()
        .args(serve_args)
        .args(["--profile-out", prof_b.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(
        std::fs::read(&prof_a).unwrap(),
        std::fs::read(&prof_b).unwrap(),
        "profile export must be deterministic per seed"
    );
    std::fs::remove_file(&trace).ok();
    std::fs::remove_file(&prof_a).ok();
    std::fs::remove_file(&prof_b).ok();
}

#[test]
fn serve_rejects_unknown_devices_and_bad_rates() {
    let out = bin()
        .args(["serve", "--suite", "rmat10", "--scale", "tiny", "--devices", "h100"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown device"));
    let out = bin()
        .args(["serve", "--suite", "rmat10", "--scale", "tiny", "--arrival-rate", "-2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn config_unknown_key_names_itself_and_the_nearest_valid_key() {
    let cpath = temp("typo.conf");
    std::fs::write(&cpath, "name = smoke\nqueu_cap = 8\n").unwrap();
    let out = bin()
        .args(["serve", "--config", cpath.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("queu_cap"), "error must name the unknown key:\n{err}");
    assert!(
        err.contains("did you mean") && err.contains("queue_cap"),
        "error must suggest the nearest valid key:\n{err}"
    );
    std::fs::remove_file(&cpath).ok();
}

#[test]
fn serve_fault_injection_end_to_end() {
    // A transient stall on shard 0 early in a saturated block-policy
    // stream: the CLI parses the spec, the scheduler aborts/requeues
    // around the outage, the conservation identity holds in the JSON
    // report, and the survivors still pass differential replay.
    let out = bin()
        .args([
            "serve", "--suite", "rmat10", "--scale", "tiny", "--queries", "32",
            "--arrival-rate", "8000", "--queue-cap", "40", "--queue-policy", "block",
            "--devices", "k20c,k40", "--max-batch", "8",
            "--fault-spec", "stall:shard=0,at=0.001,for=0.05",
            "--deadline-ms", "100", "--max-retries", "4", "--retry-backoff-ms", "0.5",
            "--verify", "--json",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("fault plan: 2 transition(s)"),
        "stall expands to Down+Up:\n{text}"
    );
    assert!(text.contains("differential replay OK"), "no replay verdict:\n{text}");
    let json_line = text.lines().find(|l| l.starts_with('{')).expect("json object");
    let v = lonestar_lb::util::Json::parse(json_line).expect("valid json");
    let field = |k: &str| v.get(k).unwrap_or_else(|| panic!("missing {k}")).as_usize().unwrap();
    assert_eq!(field("arrived"), 32);
    assert_eq!(
        field("arrived"),
        field("served") + field("dropped") + field("deadline_expired") + field("failed"),
        "conservation identity in the JSON report"
    );
    assert!(
        field("requeued") >= 1,
        "the mid-batch stall must requeue at least one attempt"
    );
    assert!(field("retries") <= field("requeued"));
}

#[test]
fn serve_rejects_bad_fault_specs() {
    for (spec, needle) in [
        ("stall:shard=0,at=1", "for"),              // missing duration
        ("stall:shard=9,at=1,for=1", "shard"),      // out of range for 2 shards
        ("frobnicate:shard=0,at=1", "frobnicate"),  // unknown clause
    ] {
        let out = bin()
            .args([
                "serve", "--suite", "rmat10", "--scale", "tiny",
                "--arrival-rate", "100", "--devices", "k20c,k40",
                "--fault-spec", spec,
            ])
            .output()
            .unwrap();
        assert!(!out.status.success(), "spec {spec:?} must be rejected");
        assert!(
            String::from_utf8_lossy(&out.stderr).contains(needle),
            "error for {spec:?} should mention {needle:?}"
        );
    }
}

#[test]
fn figures_tiny_table2() {
    let out = bin()
        .args(["figures", "table2", "--scale", "tiny"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("Table II"));
}

#[test]
fn runtime_info_works_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let out = bin().arg("runtime-info").output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("artifacts OK"));
}
