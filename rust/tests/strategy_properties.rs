//! Property-based integration tests: randomized graphs through every
//! strategy and algorithm, checked against the serial oracles, plus
//! structural invariants of the planning machinery.

use lonestar_lb::adaptive::{migrate, AdaptivePolicyKind};
use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::arena::GraphCache;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::{erdos_renyi, rmat, road_grid, RmatParams};
use lonestar_lb::graph::{Csr, Edge, Graph};
use lonestar_lb::metrics::RunMetrics;
use lonestar_lb::serving::{
    aggregate, serve_stream, synthetic_arrivals, MergedWorklist, OverflowPolicy, SchedulerConfig,
    ServeConfig,
};
use lonestar_lb::strategies::mdt::auto_mdt;
use lonestar_lb::strategies::node_split::split_graph;
use lonestar_lb::strategies::partition::{
    degree_bin, histogram_bin_order_into, merge_path_chunks, merge_path_offsets_into,
    MAX_GRID_LANES,
};
use lonestar_lb::strategies::{Schedule, StrategyKind, StrategyParams};
use lonestar_lb::util::proptest::forall;
use lonestar_lb::util::Rng;
use lonestar_lb::worklist::NodeWorklist;
use std::sync::Arc;

/// Random graph with arbitrary structure (not from the generators — raw
/// edge soup, including self loops, parallels and isolated nodes).
fn random_graph(rng: &mut Rng) -> Csr {
    let n = rng.gen_range_u32(2, 120) as usize;
    let m = rng.gen_range_u32(1, 600) as usize;
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        edges.push(Edge::new(
            rng.gen_range_u32(0, n as u32),
            rng.gen_range_u32(0, n as u32),
            rng.gen_range_inclusive_u32(1, 50),
        ));
    }
    Csr::from_edges(n, &edges).unwrap()
}

#[test]
fn every_strategy_matches_oracle_on_random_graphs() {
    forall("strategy-vs-oracle", 60, |rng| {
        let g = Arc::new(random_graph(rng));
        let source = rng.gen_range_u32(0, g.num_nodes() as u32);
        let algo = if rng.gen_f64() < 0.5 {
            AlgoKind::Bfs
        } else {
            AlgoKind::Sssp
        };
        let oracle = algo.reference(&g, source);
        for strategy in StrategyKind::ALL {
            let r = run(
                &g,
                &RunConfig {
                    algo,
                    strategy,
                    source,
                    ..Default::default()
                },
            )
            .unwrap_or_else(|e| panic!("{strategy} failed: {e}"));
            assert_eq!(r.dist, oracle, "{strategy}/{algo:?} diverged from oracle");
        }
    });
}

#[test]
fn split_graph_preserves_reachability_costs() {
    forall("split-preserves-sssp", 40, |rng| {
        let g = random_graph(rng);
        let bins = rng.gen_range_u32(2, 16) as usize;
        let decision = auto_mdt(&g, bins);
        let split = split_graph(&g, decision);

        // Structural invariants.
        assert_eq!(split.graph.num_edges(), g.num_edges(), "edges preserved");
        assert!(split.graph.max_degree() <= decision.mdt.max(1));
        assert_eq!(
            split.map.total_children() as usize,
            split.graph.num_nodes() - g.num_nodes()
        );

        // Semantic invariant: distances on original ids unchanged when the
        // NS engine runs over the split graph (children mirror parents).
        let source = rng.gen_range_u32(0, g.num_nodes() as u32);
        let oracle = lonestar_lb::graph::traversal::dijkstra(&g, source);
        let r = run(
            &Arc::new(g),
            &RunConfig {
                strategy: StrategyKind::NS,
                source,
                params: StrategyParams {
                    histogram_bins: bins,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(r.dist, oracle);
    });
}

#[test]
fn mdt_override_still_converges() {
    forall("mdt-override", 25, |rng| {
        let g = Arc::new(random_graph(rng));
        let mdt = rng.gen_range_u32(1, 12);
        let oracle = lonestar_lb::graph::traversal::bfs_levels(&g, 0);
        for strategy in [StrategyKind::NS, StrategyKind::HP] {
            let r = run(
                &g,
                &RunConfig {
                    algo: AlgoKind::Bfs,
                    strategy,
                    params: StrategyParams {
                        mdt_override: Some(mdt),
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(r.dist, oracle, "{strategy} with MDT={mdt}");
        }
    });
}

#[test]
fn metrics_counters_are_consistent() {
    forall("metrics-consistency", 30, |rng| {
        let g = Arc::new(random_graph(rng));
        for strategy in StrategyKind::ALL {
            let r = run(
                &g,
                &RunConfig {
                    strategy,
                    ..Default::default()
                },
            )
            .unwrap();
            let m = &r.metrics;
            assert!(m.updates <= m.edge_relaxations + 1,
                "{strategy}: more updates than relaxations");
            assert!(m.atomic_conflicts <= m.atomics);
            assert!(m.kernel_launches as u64 >= m.iterations as u64,
                "{strategy}: every iteration launches at least one kernel");
            assert_eq!(m.total_cycles(), m.kernel_cycles + m.overhead_cycles);
        }
    });
}

#[test]
fn generated_classes_converge_from_any_source() {
    let graphs: Vec<Arc<Csr>> = vec![
        Arc::new(rmat(9, 8 << 9, RmatParams::default(), 11).unwrap()),
        Arc::new(road_grid(20, 20, 30, 12).unwrap()),
        Arc::new(erdos_renyi(400, 1600, 20, 13).unwrap()),
    ];
    forall("any-source", 20, |rng| {
        let g = &graphs[rng.gen_index(graphs.len())];
        let source = rng.gen_range_u32(0, g.num_nodes() as u32);
        let oracle = lonestar_lb::graph::traversal::dijkstra(g, source);
        for strategy in StrategyKind::ALL {
            let r = run(
                g,
                &RunConfig {
                    strategy,
                    source,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(r.dist, oracle, "{strategy} from source {source}");
        }
    });
}

/// Random frontier over the graph: a unique node subset with cached
/// degrees, like the engine's canonical node worklists after condensing.
fn random_frontier(rng: &mut Rng, g: &Csr) -> NodeWorklist {
    let n = g.num_nodes() as u32;
    let mut picked: Vec<u32> = (0..n).collect();
    rng.shuffle(&mut picked);
    let take = rng.gen_range_u32(1, n.min(64) + 1) as usize;
    let mut wl = NodeWorklist::new();
    for &u in &picked[..take] {
        wl.push(u, g.degree(u));
    }
    wl
}

fn sorted_nodes(wl: &NodeWorklist) -> Vec<u32> {
    let mut v = wl.nodes().to_vec();
    v.sort_unstable();
    v
}

#[test]
fn migrate_node_edge_roundtrip_preserves_pending_set() {
    // nodes → EP's exploded edge frontier → nodes: the pending set is
    // preserved exactly, minus zero-out-degree nodes (which the edge
    // representation cannot carry and whose processing is a no-op).
    forall("migrate-node-edge-roundtrip", 40, |rng| {
        let g = if rng.gen_f64() < 0.5 {
            rmat(8, 2048, RmatParams::default(), rng.next_u64()).unwrap()
        } else {
            road_grid(12, 12, 9, rng.next_u64()).unwrap()
        };
        let wl = random_frontier(rng, &g);
        let edges = migrate::nodes_to_edges(&g, &wl);
        assert_eq!(
            edges.len() as u64,
            wl.total_edges(),
            "every pending edge must appear exactly once"
        );
        let back = migrate::edges_to_nodes(&g, &edges);
        let want: Vec<u32> = sorted_nodes(&wl)
            .into_iter()
            .filter(|&u| g.degree(u) > 0)
            .collect();
        assert_eq!(sorted_nodes(&back), want);
        // degrees are re-derived from the graph, so total work survives
        assert_eq!(back.total_edges(), wl.total_edges());
    });
}

#[test]
fn migrate_split_roundtrip_preserves_pending_set() {
    // nodes → NS's split-graph ids → nodes is exact: parents collapse back
    // and no pending edge is gained or lost.
    forall("migrate-split-roundtrip", 40, |rng| {
        let g = if rng.gen_f64() < 0.5 {
            rmat(8, 2048, RmatParams::default(), rng.next_u64()).unwrap()
        } else {
            road_grid(12, 12, 9, rng.next_u64()).unwrap()
        };
        let bins = rng.gen_range_u32(2, 16) as usize;
        let split = split_graph(&g, auto_mdt(&g, bins));
        let parent_of = migrate::parent_of_table(&split, g.num_nodes());
        let wl = random_frontier(rng, &g);

        let split_wl = migrate::nodes_to_split(&split, &wl);
        assert_eq!(
            split_wl.total_edges(),
            wl.total_edges(),
            "clones own exactly their parents' edges"
        );
        let back = migrate::split_to_nodes(&g, &parent_of, &split_wl);
        assert_eq!(sorted_nodes(&back), sorted_nodes(&wl));
    });
}

#[test]
fn adaptive_matches_oracle_on_random_graphs() {
    // The full acceptance property: whatever the policy decides, AD's
    // distances equal the serial oracle (same check the static strategies
    // pass). Round-robin forces migration through every representation.
    forall("adaptive-vs-oracle", 30, |rng| {
        let g = Arc::new(random_graph(rng));
        let source = rng.gen_range_u32(0, g.num_nodes() as u32);
        let algo = if rng.gen_f64() < 0.5 {
            AlgoKind::Bfs
        } else {
            AlgoKind::Sssp
        };
        let oracle = algo.reference(&g, source);
        for policy in [
            AdaptivePolicyKind::CostModel,
            AdaptivePolicyKind::Heuristic,
            AdaptivePolicyKind::RoundRobin,
        ] {
            let r = run(
                &g,
                &RunConfig {
                    algo,
                    strategy: StrategyKind::AD,
                    source,
                    params: StrategyParams {
                        adaptive_policy: policy,
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )
            .unwrap_or_else(|e| panic!("AD/{policy:?} failed: {e}"));
            assert_eq!(r.dist, oracle, "AD/{policy:?}/{algo:?} diverged from oracle");
            assert_eq!(
                r.metrics.decisions.len() as u32,
                r.metrics.iterations,
                "AD/{policy:?}: one decision per iteration"
            );
        }
    });
}

#[test]
fn merged_worklist_migration_roundtrip_preserves_tags() {
    // The serving layer's tagged merged worklist: nodes → exploded edges →
    // nodes must preserve every query's tag exactly, with the same single
    // documented exception as the untagged migration — nodes of out-degree
    // zero cannot ride in edge space. Slot counts range past 64, so the
    // multi-word tag layout is exercised alongside the single-word one
    // (generalizing the original 64-bit property).
    forall("merged-tag-roundtrip", 40, |rng| {
        let g = if rng.gen_f64() < 0.5 {
            rmat(8, 2048, RmatParams::default(), rng.next_u64()).unwrap()
        } else {
            road_grid(12, 12, 9, rng.next_u64()).unwrap()
        };
        // 1..=8 slots half the time (single-word), 60..=200 otherwise
        // (1–4 words); slots are sparse so high bits really get set.
        let capacity = if rng.gen_f64() < 0.5 {
            rng.gen_range_u32(1, 9) as usize
        } else {
            rng.gen_range_u32(60, 201) as usize
        };
        let count = rng.gen_range_u32(1, 9).min(capacity as u32) as usize;
        let mut slots: Vec<usize> = (0..count)
            .map(|_| rng.gen_index(capacity))
            .collect();
        slots.sort_unstable();
        slots.dedup();
        let frontiers: Vec<NodeWorklist> =
            slots.iter().map(|_| random_frontier(rng, &g)).collect();
        let pairs: Vec<(usize, &NodeWorklist)> = slots
            .iter()
            .copied()
            .zip(frontiers.iter())
            .collect();
        let merged = MergedWorklist::from_frontiers_with_capacity(&g, &pairs, capacity);
        assert_eq!(merged.stride(), capacity.div_ceil(64).max(1));

        // Each slot's extracted frontier equals the input frontier.
        for (slot, wl) in &pairs {
            let got = merged.query_frontier(*slot);
            assert_eq!(sorted_nodes(&got), sorted_nodes(wl), "slot {slot}");
        }

        // Tag-preserving round-trip through edge space (all words).
        let back = merged.to_edges(&g).to_nodes(&g);
        let mut want: Vec<(u32, Vec<u64>)> = Vec::new();
        for i in 0..merged.len() {
            let n = merged.nodes()[i];
            if g.degree(n) > 0 {
                want.push((n, merged.mask_words(i).to_vec()));
            }
        }
        want.sort_unstable();
        let mut got: Vec<(u32, Vec<u64>)> = Vec::new();
        for i in 0..back.len() {
            got.push((back.nodes()[i], back.mask_words(i).to_vec()));
        }
        got.sort_unstable();
        assert_eq!(got, want, "tags must survive the edge round-trip");

        // The sort-based builder still matches the BTreeMap oracle at
        // every stride.
        let oracle = MergedWorklist::from_frontiers_btree_with_capacity(&g, &pairs, capacity);
        assert_eq!(merged, oracle, "builder == btree oracle (capacity {capacity})");
    });
}

#[test]
fn scheduler_conserves_queries_and_admits_fifo() {
    // The admission-control conservation law and FIFO admission order,
    // across random rates, queue caps, pool shapes and both overflow
    // policies: `arrived == admitted + dropped`, `admitted == served` at
    // drain, and queries leave the queue exactly in arrival order minus
    // the dropped ones.
    let g = std::sync::Arc::new(erdos_renyi(200, 800, 11, 17).unwrap());
    forall("scheduler-conservation", 12, |rng| {
        let count = rng.gen_range_u32(10, 60) as usize;
        let mean_gap_ps = [1_000u64, 100_000, 10_000_000, 1_000_000_000]
            [rng.gen_index(4)];
        let queue_cap = rng.gen_range_u32(1, 20) as usize;
        let max_batch = rng.gen_range_u32(1, 12) as usize;
        let shards = rng.gen_range_u32(1, 4) as usize;
        let overflow = if rng.gen_f64() < 0.5 {
            OverflowPolicy::Drop
        } else {
            OverflowPolicy::Block
        };
        let devices: Vec<_> = (0..shards)
            .map(|i| match i % 3 {
                0 => lonestar_lb::sim::DeviceSpec::k20c(),
                1 => lonestar_lb::sim::DeviceSpec::k40(),
                _ => lonestar_lb::sim::DeviceSpec::gtx680(),
            })
            .collect();
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                strategy: StrategyKind::BS,
                devices,
                max_batch,
                ..Default::default()
            },
            queue_cap,
            overflow,
            ..Default::default()
        };
        let arrivals = synthetic_arrivals(&g, count, 0.5, mean_gap_ps, rng.next_u64());
        let label = format!(
            "count={count} gap={mean_gap_ps} cap={queue_cap} batch={max_batch} \
             shards={shards} {overflow:?}"
        );
        let report = serve_stream(&g, arrivals.clone(), &cfg, &GraphCache::new())
            .unwrap_or_else(|e| panic!("{label}: {e}"));
        assert_eq!(report.arrived, count as u64, "{label}");
        assert_eq!(
            report.arrived,
            report.admitted + report.dropped.len() as u64,
            "{label}: arrived == admitted + dropped"
        );
        assert_eq!(
            report.admitted,
            report.served() as u64,
            "{label}: admitted == served at drain"
        );
        if overflow == OverflowPolicy::Block {
            assert!(report.dropped.is_empty(), "{label}: block never sheds");
        }
        // FIFO: placement order == arrival order minus the dropped ids.
        let dropped: std::collections::BTreeSet<u32> =
            report.dropped.iter().map(|q| q.id).collect();
        let expected: Vec<u32> = arrivals
            .iter()
            .map(|a| a.query.id)
            .filter(|id| !dropped.contains(id))
            .collect();
        assert_eq!(
            report.placed_order, expected,
            "{label}: queries must leave the queue in admission order"
        );
        // Aggregate counters mirror the report.
        let totals = report.totals();
        assert_eq!(totals.admitted, report.admitted, "{label}");
        assert_eq!(totals.dropped, report.dropped.len() as u64, "{label}");
        assert_eq!(totals.queue_peak, report.queue_peak, "{label}");
        assert!(totals.queue_peak <= queue_cap as u64, "{label}");
    });
}

#[test]
fn batch_metrics_aggregation_is_permutation_invariant() {
    // The shard aggregation is a commutative fold (sums and maxes), so the
    // order queries/shards are folded in can never change the report.
    forall("aggregate-permutation", 30, |rng| {
        let k = rng.gen_range_u32(1, 9) as usize;
        let mut metrics: Vec<RunMetrics> = (0..k)
            .map(|_| RunMetrics {
                kernel_cycles: rng.next_u64() % 1_000_000,
                overhead_cycles: rng.next_u64() % 1_000_000,
                iterations: rng.next_u32() % 1000,
                kernel_launches: rng.next_u32() % 1000,
                edge_relaxations: rng.next_u64() % 1_000_000,
                inspector_passes: rng.next_u64() % 1000,
                policy_decisions: rng.next_u64() % 1000,
                strategy_switches: rng.next_u64() % 100,
                peak_memory_bytes: rng.next_u64() % 1_000_000,
                ..Default::default()
            })
            .collect();
        let before = aggregate(metrics.iter());
        rng.shuffle(&mut metrics);
        let after = aggregate(metrics.iter());
        assert_eq!(before, after, "aggregation must be order-independent");
    });
}

#[test]
fn adaptive_decision_trace_is_deterministic() {
    let g = Arc::new(rmat(10, 8 << 10, RmatParams::default(), 21).unwrap());
    let cfg = RunConfig {
        strategy: StrategyKind::AD,
        ..Default::default()
    };
    let a = run(&g, &cfg).unwrap();
    let b = run(&g, &cfg).unwrap();
    assert_eq!(a.dist, b.dist);
    assert_eq!(a.metrics.total_cycles(), b.metrics.total_cycles());
    assert_eq!(a.metrics.decisions, b.metrics.decisions);
    assert_eq!(a.metrics.strategy_switches, b.metrics.strategy_switches);
}

#[test]
fn merge_path_partition_covers_every_position_exactly_once() {
    // The merge-path balance bound over arbitrary (total, width) shapes:
    // boundaries are monotone, chunks are disjoint and cover 0..total with
    // no gap or overlap, and per-chunk work differs by at most one.
    forall("merge-path-partition", 60, |rng| {
        let total = rng.gen_range_u32(0, 5_000) as usize;
        let width = [1u32, 32, 128, 1024][rng.gen_index(4)];
        let chunks = merge_path_chunks(total, width);
        assert!(chunks >= 1, "always at least one chunk");
        let mut out = Vec::new();
        merge_path_offsets_into(total, chunks, &mut out);
        assert_eq!(out.len(), chunks as usize + 1);
        assert_eq!(out[0], 0);
        assert_eq!(*out.last().unwrap() as usize, total);

        // Exactly-once coverage: every position lands in one chunk.
        let mut seen = vec![0u8; total];
        let mut spans = Vec::with_capacity(chunks as usize);
        for w in out.windows(2) {
            assert!(w[0] <= w[1], "boundaries must be monotone");
            for p in w[0]..w[1] {
                seen[p as usize] += 1;
            }
            spans.push(w[1] - w[0]);
        }
        assert!(seen.iter().all(|&c| c == 1), "each position covered once");

        // Balance bound: spans within ±1; below the grid cap each group
        // fits its width (one lockstep step per lane).
        let (min, max) = (
            spans.iter().min().copied().unwrap(),
            spans.iter().max().copied().unwrap(),
        );
        assert!(max - min <= 1, "spans must differ by at most one");
        if total > 0 && total <= MAX_GRID_LANES {
            assert!(max <= width, "below the cap a chunk never outgrows its lanes");
        }
    });
}

#[test]
fn histogram_order_is_a_balanced_stable_permutation_of_random_frontiers() {
    // The histogram partitioner over real frontier degree vectors: the
    // output is a permutation (every slot exactly once), bins ascend,
    // original order survives within a bin, and within one bin the
    // heaviest slot carries less than 2x the lightest — the binned
    // balance bound.
    forall("histogram-bin-order", 60, |rng| {
        let g = random_graph(rng);
        let wl = random_frontier(rng, &g);
        let degrees: Vec<u32> = wl.nodes().iter().map(|&u| g.degree(u)).collect();
        let (mut counts, mut order) = (Vec::new(), Vec::new());
        histogram_bin_order_into(&degrees, &mut counts, &mut order);

        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(
            sorted,
            (0..degrees.len() as u32).collect::<Vec<_>>(),
            "output must be a permutation of the slots"
        );
        for w in order.windows(2) {
            let (a, b) = (degrees[w[0] as usize], degrees[w[1] as usize]);
            let (ba, bb) = (degree_bin(a), degree_bin(b));
            assert!(ba <= bb, "bins must ascend");
            if ba == bb {
                assert!(w[0] < w[1], "equal bins keep frontier order");
                // Balance bound inside a bin: max < 2 * min (isolated
                // nodes share bin 0 at zero work).
                let (lo, hi) = (a.min(b), a.max(b));
                assert!(hi < 2 * lo.max(1), "within-bin skew must stay under 2x");
            }
        }
    });
}

#[test]
fn composed_schedules_match_oracle_on_random_graphs() {
    // The new composed balancers through the same edge-soup gauntlet the
    // monolithic strategies pass: self loops, parallel edges, isolated
    // nodes, zero-degree frontiers.
    forall("composed-vs-oracle", 40, |rng| {
        let g = Arc::new(random_graph(rng));
        let source = rng.gen_range_u32(0, g.num_nodes() as u32);
        let algo = if rng.gen_f64() < 0.5 {
            AlgoKind::Bfs
        } else {
            AlgoKind::Sssp
        };
        let oracle = algo.reference(&g, source);
        for s in Schedule::NEW {
            let r = run(
                &g,
                &RunConfig {
                    algo,
                    strategy: StrategyKind::Composed(s),
                    source,
                    ..Default::default()
                },
            )
            .unwrap_or_else(|e| panic!("{s} failed: {e}"));
            assert_eq!(r.dist, oracle, "{s}/{algo:?} diverged from oracle");
        }
    });
}

#[test]
fn deterministic_metrics_across_repeat_runs() {
    let g = Arc::new(rmat(10, 8 << 10, RmatParams::default(), 21).unwrap());
    for strategy in StrategyKind::ALL {
        let cfg = RunConfig {
            strategy,
            ..Default::default()
        };
        let a = run(&g, &cfg).unwrap();
        let b = run(&g, &cfg).unwrap();
        assert_eq!(a.dist, b.dist);
        assert_eq!(a.metrics.total_cycles(), b.metrics.total_cycles());
        assert_eq!(a.metrics.atomics, b.metrics.atomics);
        assert_eq!(a.metrics.peak_memory_bytes, b.metrics.peak_memory_bytes);
    }
}
