//! Property-based integration tests: randomized graphs through every
//! strategy and algorithm, checked against the serial oracles, plus
//! structural invariants of the planning machinery.

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::{erdos_renyi, rmat, road_grid, RmatParams};
use lonestar_lb::graph::{Csr, Edge, Graph};
use lonestar_lb::strategies::mdt::auto_mdt;
use lonestar_lb::strategies::node_split::split_graph;
use lonestar_lb::strategies::{StrategyKind, StrategyParams};
use lonestar_lb::util::proptest::forall;
use lonestar_lb::util::Rng;
use std::sync::Arc;

/// Random graph with arbitrary structure (not from the generators — raw
/// edge soup, including self loops, parallels and isolated nodes).
fn random_graph(rng: &mut Rng) -> Csr {
    let n = rng.gen_range_u32(2, 120) as usize;
    let m = rng.gen_range_u32(1, 600) as usize;
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        edges.push(Edge::new(
            rng.gen_range_u32(0, n as u32),
            rng.gen_range_u32(0, n as u32),
            rng.gen_range_inclusive_u32(1, 50),
        ));
    }
    Csr::from_edges(n, &edges).unwrap()
}

#[test]
fn every_strategy_matches_oracle_on_random_graphs() {
    forall("strategy-vs-oracle", 60, |rng| {
        let g = Arc::new(random_graph(rng));
        let source = rng.gen_range_u32(0, g.num_nodes() as u32);
        let algo = if rng.gen_f64() < 0.5 {
            AlgoKind::Bfs
        } else {
            AlgoKind::Sssp
        };
        let oracle = algo.reference(&g, source);
        for strategy in StrategyKind::ALL {
            let r = run(
                &g,
                &RunConfig {
                    algo,
                    strategy,
                    source,
                    ..Default::default()
                },
            )
            .unwrap_or_else(|e| panic!("{strategy} failed: {e}"));
            assert_eq!(r.dist, oracle, "{strategy}/{algo:?} diverged from oracle");
        }
    });
}

#[test]
fn split_graph_preserves_reachability_costs() {
    forall("split-preserves-sssp", 40, |rng| {
        let g = random_graph(rng);
        let bins = rng.gen_range_u32(2, 16) as usize;
        let decision = auto_mdt(&g, bins);
        let split = split_graph(&g, decision);

        // Structural invariants.
        assert_eq!(split.graph.num_edges(), g.num_edges(), "edges preserved");
        assert!(split.graph.max_degree() <= decision.mdt.max(1));
        assert_eq!(
            split.map.total_children() as usize,
            split.graph.num_nodes() - g.num_nodes()
        );

        // Semantic invariant: distances on original ids unchanged when the
        // NS engine runs over the split graph (children mirror parents).
        let source = rng.gen_range_u32(0, g.num_nodes() as u32);
        let oracle = lonestar_lb::graph::traversal::dijkstra(&g, source);
        let r = run(
            &Arc::new(g),
            &RunConfig {
                strategy: StrategyKind::NS,
                source,
                params: StrategyParams {
                    histogram_bins: bins,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(r.dist, oracle);
    });
}

#[test]
fn mdt_override_still_converges() {
    forall("mdt-override", 25, |rng| {
        let g = Arc::new(random_graph(rng));
        let mdt = rng.gen_range_u32(1, 12);
        let oracle = lonestar_lb::graph::traversal::bfs_levels(&g, 0);
        for strategy in [StrategyKind::NS, StrategyKind::HP] {
            let r = run(
                &g,
                &RunConfig {
                    algo: AlgoKind::Bfs,
                    strategy,
                    params: StrategyParams {
                        mdt_override: Some(mdt),
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(r.dist, oracle, "{strategy} with MDT={mdt}");
        }
    });
}

#[test]
fn metrics_counters_are_consistent() {
    forall("metrics-consistency", 30, |rng| {
        let g = Arc::new(random_graph(rng));
        for strategy in StrategyKind::ALL {
            let r = run(
                &g,
                &RunConfig {
                    strategy,
                    ..Default::default()
                },
            )
            .unwrap();
            let m = &r.metrics;
            assert!(m.updates <= m.edge_relaxations + 1,
                "{strategy}: more updates than relaxations");
            assert!(m.atomic_conflicts <= m.atomics);
            assert!(m.kernel_launches as u64 >= m.iterations as u64,
                "{strategy}: every iteration launches at least one kernel");
            assert_eq!(m.total_cycles(), m.kernel_cycles + m.overhead_cycles);
        }
    });
}

#[test]
fn generated_classes_converge_from_any_source() {
    let graphs: Vec<Arc<Csr>> = vec![
        Arc::new(rmat(9, 8 << 9, RmatParams::default(), 11).unwrap()),
        Arc::new(road_grid(20, 20, 30, 12).unwrap()),
        Arc::new(erdos_renyi(400, 1600, 20, 13).unwrap()),
    ];
    forall("any-source", 20, |rng| {
        let g = &graphs[rng.gen_index(graphs.len())];
        let source = rng.gen_range_u32(0, g.num_nodes() as u32);
        let oracle = lonestar_lb::graph::traversal::dijkstra(g, source);
        for strategy in StrategyKind::ALL {
            let r = run(
                g,
                &RunConfig {
                    strategy,
                    source,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(r.dist, oracle, "{strategy} from source {source}");
        }
    });
}

#[test]
fn deterministic_metrics_across_repeat_runs() {
    let g = Arc::new(rmat(10, 8 << 10, RmatParams::default(), 21).unwrap());
    for strategy in StrategyKind::ALL {
        let cfg = RunConfig {
            strategy,
            ..Default::default()
        };
        let a = run(&g, &cfg).unwrap();
        let b = run(&g, &cfg).unwrap();
        assert_eq!(a.dist, b.dist);
        assert_eq!(a.metrics.total_cycles(), b.metrics.total_cycles());
        assert_eq!(a.metrics.atomics, b.metrics.atomics);
        assert_eq!(a.metrics.peak_memory_bytes, b.metrics.peak_memory_bytes);
    }
}
