//! Differential oracle for the composable schedule algebra
//! (`rust/src/strategies/schedule.rs`).
//!
//! The algebra's contract has two halves, and each gets pinned here:
//!
//! * **Aliases are the originals.** Every `granularity/order` point that
//!   claims equivalence to one of the paper's five monolithic strategies
//!   must be *bit-identical* to it — same distances, same cycle and
//!   counter metrics, same inspection/decision work — across the
//!   generator suite (grid/ER/RMAT/road) for both BFS and SSSP.
//! * **The new points earn their keep.** The genuinely new compositions
//!   (warp/block merge-path, block histogram-binned) must produce
//!   oracle-correct distances everywhere, and on the skewed suite graph
//!   the merge-path balancers must eliminate the straggler cycles all
//!   five monolithic strategies pay.
//!
//! Plus the AD-facing invariant mirrored from `paper_claims.rs`: an
//! adaptive run whose candidate set includes composed schedules never
//! *picks* one whose transient scratch cannot fit the device budget.

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::{erdos_renyi, paper_suite, rmat, road_grid, RmatParams, SuiteScale};
use lonestar_lb::graph::traversal::{bfs_levels, dijkstra, hub_source};
use lonestar_lb::graph::Csr;
use lonestar_lb::metrics::RunMetrics;
use lonestar_lb::strategies::{Schedule, StrategyKind, StrategyParams};
use std::sync::Arc;

/// The generator families named by the algebra's differential contract.
fn generator_suite() -> Vec<(&'static str, Arc<Csr>)> {
    vec![
        ("grid", Arc::new(road_grid(8, 12, 1, 11).unwrap())),
        ("er", Arc::new(erdos_renyi(192, 768, 10, 3).unwrap())),
        ("rmat", Arc::new(rmat(8, 2048, RmatParams::default(), 31).unwrap())),
        ("road", Arc::new(road_grid(18, 18, 100, 13).unwrap())),
    ]
}

/// The five lowered points that alias the paper's strategies, with the
/// monolithic original each must be indistinguishable from.
const ALIASES: [(&str, StrategyKind); 5] = [
    ("thread/sorted", StrategyKind::BS),
    ("cta/sorted", StrategyKind::EP),
    ("thread/merge-path", StrategyKind::WD),
    ("block/sorted", StrategyKind::NS),
    ("warp/sorted", StrategyKind::HP),
];

/// Field-by-field metrics equality (`RunMetrics` has no `PartialEq`; the
/// host wall-clock `host_ns` is the one legitimately nondeterministic
/// field and is excluded).
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, ctx: &str) {
    assert_eq!(a.kernel_cycles, b.kernel_cycles, "{ctx}: kernel_cycles");
    assert_eq!(a.overhead_cycles, b.overhead_cycles, "{ctx}: overhead_cycles");
    assert_eq!(a.iterations, b.iterations, "{ctx}: iterations");
    assert_eq!(a.kernel_launches, b.kernel_launches, "{ctx}: kernel_launches");
    assert_eq!(a.edge_relaxations, b.edge_relaxations, "{ctx}: edge_relaxations");
    assert_eq!(a.updates, b.updates, "{ctx}: updates");
    assert_eq!(a.atomics, b.atomics, "{ctx}: atomics");
    assert_eq!(a.atomic_conflicts, b.atomic_conflicts, "{ctx}: atomic_conflicts");
    assert_eq!(a.mem_transactions, b.mem_transactions, "{ctx}: mem_transactions");
    assert_eq!(
        a.peak_worklist_entries, b.peak_worklist_entries,
        "{ctx}: peak_worklist_entries"
    );
    assert_eq!(a.condensed_away, b.condensed_away, "{ctx}: condensed_away");
    assert_eq!(a.peak_memory_bytes, b.peak_memory_bytes, "{ctx}: peak_memory_bytes");
    assert_eq!(a.strategy_switches, b.strategy_switches, "{ctx}: strategy_switches");
    assert_eq!(a.inspector_passes, b.inspector_passes, "{ctx}: inspector_passes");
    assert_eq!(a.policy_decisions, b.policy_decisions, "{ctx}: policy_decisions");
    assert_eq!(a.decisions, b.decisions, "{ctx}: decision trace");
    assert_eq!(a.profiled_kernels, b.profiled_kernels, "{ctx}: profiled_kernels");
    assert_eq!(a.warp_cycles_hist, b.warp_cycles_hist, "{ctx}: warp_cycles_hist");
    assert_eq!(a.imbalance_hist, b.imbalance_hist, "{ctx}: imbalance_hist");
    assert_eq!(
        a.imbalance_overhead_cycles, b.imbalance_overhead_cycles,
        "{ctx}: imbalance_overhead_cycles"
    );
    assert_eq!(
        a.peak_imbalance_x1000, b.peak_imbalance_x1000,
        "{ctx}: peak_imbalance_x1000"
    );
    assert_eq!(a.scratch_created, b.scratch_created, "{ctx}: scratch_created");
    assert_eq!(a.scratch_reused, b.scratch_reused, "{ctx}: scratch_reused");
    assert_eq!(a.scratch_peak_bytes, b.scratch_peak_bytes, "{ctx}: scratch_peak_bytes");
}

#[test]
fn alias_compositions_are_bit_identical_to_their_monolithic_originals() {
    for (gname, g) in generator_suite() {
        for algo in [AlgoKind::Bfs, AlgoKind::Sssp] {
            for (spec, original) in ALIASES {
                let composed: StrategyKind = spec.parse().unwrap();
                assert!(
                    matches!(composed, StrategyKind::Composed(s) if s.alias() == Some(original)),
                    "{spec} must parse to the alias of {original}"
                );
                let cfg = |strategy| RunConfig {
                    algo,
                    strategy,
                    ..Default::default()
                };
                let a = run(&g, &cfg(composed)).unwrap();
                let b = run(&g, &cfg(original)).unwrap();
                let ctx = format!("{gname}/{algo:?}/{spec} vs {original}");
                assert_eq!(a.dist, b.dist, "{ctx}: distances");
                assert_metrics_identical(&a.metrics, &b.metrics, &ctx);
            }
        }
    }
}

#[test]
fn new_compositions_match_the_reference_oracle_across_the_generator_suite() {
    for (gname, g) in generator_suite() {
        for algo in [AlgoKind::Bfs, AlgoKind::Sssp] {
            let oracle = match algo {
                AlgoKind::Bfs => bfs_levels(&g, 0),
                AlgoKind::Sssp => dijkstra(&g, 0),
            };
            for s in Schedule::NEW {
                let r = run(
                    &g,
                    &RunConfig {
                        algo,
                        strategy: StrategyKind::Composed(s),
                        ..Default::default()
                    },
                )
                .unwrap();
                assert_eq!(r.dist, oracle, "{gname}/{algo:?}/{s}: distances vs oracle");
                assert!(r.metrics.iterations > 0, "{gname}/{algo:?}/{s}: ran iterations");
                assert!(
                    r.metrics.edge_relaxations > 0,
                    "{gname}/{algo:?}/{s}: relaxed edges"
                );
            }
        }
    }
}

/// The CLI-visible grammar: `granularity/order` spellings parse into
/// `StrategyKind::Composed` and round-trip through their labels; points
/// with no lowering are rejected with the supported set in the message.
#[test]
fn composed_grammar_round_trips_and_rejects_unlowered_points() {
    for s in Schedule::NEW {
        let k: StrategyKind = s.label().parse().unwrap();
        assert_eq!(k, StrategyKind::Composed(s));
        assert_eq!(k.label(), s.label());
    }
    for (spec, original) in ALIASES {
        let k: StrategyKind = spec.parse().unwrap();
        assert_eq!(k.label(), spec);
        assert!(matches!(k, StrategyKind::Composed(s) if s.alias() == Some(original)));
    }
    for bad in ["cta/merge-path", "warp/histogram-binned", "warp", "warp/zigzag", "x/y"] {
        assert!(
            bad.parse::<StrategyKind>().is_err(),
            "{bad:?} must be rejected"
        );
    }
}

/// The payoff claim, in simulated cycles: on the skewed suite graph the
/// merge-path balancers run their relaxation phase dense over evenly split
/// chunks, so every committed warp costs the same flat coalesced step and
/// the device never idles behind a straggler warp. All five monolithic
/// strategies pay a nonzero straggler bill there (that is the paper's
/// core imbalance observation), so the new balancers must strictly
/// undercut every one of them — on straggler cycles *and* on the peak
/// per-kernel imbalance factor.
#[test]
fn merge_path_balancers_eliminate_straggler_cycles_on_the_skewed_suite_graph() {
    let entry = paper_suite(SuiteScale::Tiny)
        .into_iter()
        .find(|e| e.spec.skew_class() == "skewed")
        .expect("the paper suite always carries a skewed graph");
    let g = Arc::new(entry.spec.generate(lonestar_lb::graph::generators::suite::DEFAULT_SEED).unwrap());
    let source = hub_source(&g);
    let measure = |strategy| {
        run(
            &g,
            &RunConfig {
                algo: AlgoKind::Sssp,
                strategy,
                source,
                // Budget off so EP/NS complete — the comparison needs all
                // five monolithic runs to finish.
                enforce_budget: false,
                ..Default::default()
            },
        )
        .unwrap()
        .metrics
    };

    let monolithic: Vec<(StrategyKind, RunMetrics)> =
        StrategyKind::ALL.into_iter().map(|k| (k, measure(k))).collect();
    for s in [Schedule::WARP_MERGE_PATH, Schedule::BLOCK_MERGE_PATH] {
        let m = measure(StrategyKind::Composed(s));
        assert!(m.profiled_kernels > 0, "{s}: profiler saw composed kernels");
        assert_eq!(
            m.imbalance_overhead_cycles, 0,
            "{s}: dense merge-path warps are flat — zero straggler cycles"
        );
        for (k, base) in &monolithic {
            assert!(
                m.imbalance_overhead_cycles < base.imbalance_overhead_cycles,
                "{s} straggler cycles ({}) must undercut {} ({})",
                m.imbalance_overhead_cycles,
                k.label(),
                base.imbalance_overhead_cycles
            );
            assert!(
                m.peak_imbalance() < base.peak_imbalance(),
                "{s} peak imbalance ({}) must undercut {} ({})",
                m.peak_imbalance(),
                k.label(),
                base.peak_imbalance()
            );
        }
    }
}

/// Mirror of the `paper_claims.rs` AD invariant, widened to the composed
/// candidate set: the adaptive engine's decision trace never contains a
/// schedule whose standalone run hits the memory wall, and the run stays
/// oracle-correct with composed candidates in play.
#[test]
fn ad_with_composed_candidates_never_picks_a_memory_infeasible_schedule() {
    for entry in paper_suite(SuiteScale::Tiny) {
        if entry.spec.skew_class() != "skewed" {
            continue; // the paper's memory-caveat graphs
        }
        let seed = lonestar_lb::graph::generators::suite::DEFAULT_SEED;
        let g = Arc::new(entry.spec.generate(seed).unwrap());
        let source = hub_source(&g);
        let params = StrategyParams {
            composed_candidates: Schedule::NEW.to_vec(),
            ..Default::default()
        };

        // Which composed schedules actually hit the wall standalone.
        let mut infeasible = Vec::new();
        for s in Schedule::NEW {
            let r = run(
                &g,
                &RunConfig {
                    algo: AlgoKind::Sssp,
                    strategy: StrategyKind::Composed(s),
                    source,
                    enforce_budget: true,
                    ..Default::default()
                },
            );
            match r {
                Err(e) if e.is_oom() => infeasible.push(s.label()),
                Err(e) => panic!("{}/{s}: {e}", entry.name),
                Ok(_) => {}
            }
        }

        let ad = run(
            &g,
            &RunConfig {
                algo: AlgoKind::Sssp,
                strategy: StrategyKind::AD,
                source,
                enforce_budget: true,
                params: params.clone(),
                ..Default::default()
            },
        )
        .unwrap_or_else(|e| panic!("{}: AD must fit the budget: {e}", entry.name));
        assert_eq!(
            ad.dist,
            dijkstra(&g, source),
            "{}: AD with composed candidates stays oracle-correct",
            entry.name
        );
        assert!(!ad.metrics.decisions.is_empty());
        for d in &ad.metrics.decisions {
            assert!(
                !infeasible.contains(&d.strategy),
                "{}: AD chose {} despite its scratch not fitting (infeasible: {:?})",
                entry.name,
                d.strategy,
                infeasible
            );
        }
    }
}
