//! The parallel scheduler's determinism proof: for every seeded
//! configuration, `workers = 1`, `workers = 2` and one-worker-per-shard
//! produce **byte-identical** outputs — the `ScheduleReport` JSON, the
//! Chrome trace (`--trace-out`), the profile JSON (`--profile-out`) and
//! the Prometheus exposition (`--metrics-out`) — and repeated runs at the
//! same worker count are self-identical (no fold-order races).
//!
//! Why this holds by construction: every ordering decision (admission,
//! placement, launch, trace merge, report fold) happens on the
//! coordinator in fixed shard order; worker threads only compute batch
//! results, which are pure functions of their inputs. These tests are
//! the regression net under that argument — any future change that lets
//! thread scheduling leak into an export fails them loudly.

use lonestar_lb::arena::GraphCache;
use lonestar_lb::graph::generators::{rmat, RmatParams};
use lonestar_lb::graph::Csr;
use lonestar_lb::serving::{
    serve_stream_traced, synthetic_arrivals, OverflowPolicy, SchedulerConfig, ServeConfig,
};
use lonestar_lb::sim::DeviceSpec;
use lonestar_lb::telemetry::{chrome_trace, profile_report, TraceSink};
use std::sync::Arc;

const POOL_NAMES: [&str; 3] = ["k20c", "k40", "gtx680"];

fn pool() -> Vec<DeviceSpec> {
    vec![DeviceSpec::k20c(), DeviceSpec::k40(), DeviceSpec::gtx680()]
}

fn graph() -> Arc<Csr> {
    Arc::new(rmat(9, 4096, RmatParams::default(), 42).unwrap())
}

/// Every export surface of one seeded run, as bytes.
struct RunArtifacts {
    report_json: String,
    trace: String,
    profile: String,
    prometheus: String,
}

fn run(
    g: &Arc<Csr>,
    seed: u64,
    overflow: OverflowPolicy,
    workers: usize,
    trace_capacity: usize,
) -> RunArtifacts {
    let cfg = SchedulerConfig {
        serve: ServeConfig {
            devices: pool(),
            max_batch: 12,
            ..Default::default()
        },
        queue_cap: 24,
        overflow,
        collect_distances: true,
        workers,
        ..Default::default()
    };
    // A brisk stream: bursts deep enough that every shard runs several
    // batches and the overflow policy actually fires.
    let arrivals = synthetic_arrivals(g, 72, 0.5, 60_000, seed);
    let shard_ppc: Vec<u64> = cfg.serve.devices.iter().map(|d| d.ps_per_cycle()).collect();
    let mut sink = TraceSink::with_capacity(trace_capacity);
    let report =
        serve_stream_traced(g, arrivals, &cfg, &GraphCache::new(), Some(&mut sink)).unwrap();
    RunArtifacts {
        report_json: report.to_json().to_string(),
        trace: chrome_trace(&sink, &POOL_NAMES),
        profile: profile_report(&sink, &shard_ppc).to_string(),
        prometheus: report.prometheus(Some(&sink)),
    }
}

#[test]
fn exports_are_byte_identical_across_worker_counts() {
    let g = graph();
    for seed in [3u64, 1911] {
        for overflow in [OverflowPolicy::Drop, OverflowPolicy::Block] {
            let baseline = run(&g, seed, overflow, 1, 1 << 14);
            // 2 (shards share a worker) and 3 (one worker per shard — also
            // what `workers: 0` resolves to for this pool).
            for workers in [2usize, 3] {
                let par = run(&g, seed, overflow, workers, 1 << 14);
                let label = format!("seed={seed} {overflow:?} workers={workers}");
                assert_eq!(baseline.report_json, par.report_json, "{label}: report");
                assert_eq!(baseline.trace, par.trace, "{label}: chrome trace");
                assert_eq!(baseline.profile, par.profile, "{label}: profile");
                assert_eq!(baseline.prometheus, par.prometheus, "{label}: prometheus");
            }
        }
    }
}

#[test]
fn repeated_parallel_runs_are_self_identical() {
    // Same worker count, many repetitions: if fold order ever depended on
    // which thread finished first, this would flake. Run it enough times
    // that a race has a real chance to interleave differently.
    let g = graph();
    let first = run(&g, 7, OverflowPolicy::Drop, 3, 1 << 14);
    for round in 0..5 {
        let again = run(&g, 7, OverflowPolicy::Drop, 3, 1 << 14);
        assert_eq!(first.report_json, again.report_json, "round {round}: report");
        assert_eq!(first.trace, again.trace, "round {round}: trace");
        assert_eq!(first.profile, again.profile, "round {round}: profile");
        assert_eq!(first.prometheus, again.prometheus, "round {round}: prometheus");
    }
}

#[test]
fn wrap_around_rings_still_merge_byte_identically() {
    // A deliberately tiny ring: both the per-shard worker rings and the
    // main sink wrap several times, exercising `TraceSink::absorb`'s
    // lost-event accounting. The sequential/parallel equality must hold
    // even when events are being discarded.
    let g = graph();
    let baseline = run(&g, 11, OverflowPolicy::Block, 1, 96);
    for workers in [2usize, 3] {
        let par = run(&g, 11, OverflowPolicy::Block, workers, 96);
        assert_eq!(
            baseline.trace, par.trace,
            "workers={workers}: wrapped trace must still match"
        );
        assert_eq!(
            baseline.prometheus, par.prometheus,
            "workers={workers}: lifetime counters must survive the wrap"
        );
    }
}

#[test]
fn workers_zero_matches_one_per_shard() {
    let g = graph();
    let auto = run(&g, 5, OverflowPolicy::Drop, 0, 1 << 14);
    let explicit = run(&g, 5, OverflowPolicy::Drop, 3, 1 << 14);
    assert_eq!(auto.report_json, explicit.report_json);
    assert_eq!(auto.trace, explicit.trace);
    // Clamping: more workers than shards behaves like one per shard.
    let clamped = run(&g, 5, OverflowPolicy::Drop, 64, 1 << 14);
    assert_eq!(auto.report_json, clamped.report_json);
    assert_eq!(auto.trace, clamped.trace);
}
