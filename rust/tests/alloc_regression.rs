//! Allocation-regression suite: once the scratch arena is warm, the
//! steady-state iterations of every strategy must perform **zero** heap
//! allocations — the contract of `rust/src/arena/`.
//!
//! A counting `#[global_allocator]` (test-binary only — it never ships in
//! the library) wraps `System` and tallies every `alloc`/`alloc_zeroed`/
//! `realloc`. Each strategy is driven twice over the same deterministic
//! problem: a dry run records the per-iteration frontier sizes and (for
//! AD) the decision trace, which identifies the warm-up horizon — the
//! frontier-peak iteration, after which every pooled buffer has seen its
//! high-water capacity. The measured run then asserts a zero allocation
//! delta for every post-warm-up iteration, exempting only AD iterations
//! that migrate or switch mode (a representation rebuild is a real,
//! acknowledged allocation — it is the *steady* state that must be free).
//!
//! The whole suite is one `#[test]` so no concurrent test pollutes the
//! process-wide counters.

use lonestar_lb::algorithms::{AlgoKind, NativeRelaxer};
use lonestar_lb::arena::GraphCache;
use lonestar_lb::coordinator::ExecCtx;
use lonestar_lb::graph::generators::{erdos_renyi, road_grid};
use lonestar_lb::graph::Csr;
use lonestar_lb::serving::{
    Arrival, FaultEvent, FaultKind, FaultPlan, OverflowPolicy, Query, Scheduler,
    SchedulerConfig, ServeConfig,
};
use lonestar_lb::sim::DeviceSpec;
use lonestar_lb::strategies::{build_strategy, StrategyKind, StrategyParams};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn snapshot() -> (u64, u64) {
    (ALLOC_CALLS.load(Relaxed), ALLOC_BYTES.load(Relaxed))
}

/// Drive `kind` over `g` twice (dry + measured) and assert the zero-alloc
/// steady state. `min_steady` guards the test against degenerating into a
/// vacuous pass when the traversal is too short to have one.
fn assert_zero_alloc_steady_state(
    kind: StrategyKind,
    g: &Arc<Csr>,
    label: &str,
    min_steady: usize,
) {
    let dev = DeviceSpec::k20c();
    let params = StrategyParams::default();

    // Dry run: per-iteration frontier sizes + AD's decision trace.
    let mut dry = build_strategy(kind, g.clone(), params.clone());
    let mut ctx = ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer));
    dry.init(&mut ctx, 0).expect("init");
    let mut pending: Vec<usize> = Vec::new();
    while dry.pending() > 0 {
        pending.push(dry.pending());
        dry.run_iteration(&mut ctx).expect("dry iteration");
        assert!(pending.len() < 100_000, "{label}/{kind}: non-convergence");
    }
    let total = pending.len();
    let decisions = ctx.metrics.decisions.clone();
    let exempt: Vec<bool> = (0..total)
        .map(|i| match decisions.get(i) {
            // A migration (or any mode switch — a first entry into HP
            // sizes its sub-list) legitimately builds a representation.
            Some(d) => {
                d.migrated || (i > 0 && decisions[i - 1].strategy != d.strategy)
            }
            None => false,
        })
        .collect();
    let peak = pending
        .iter()
        .enumerate()
        .max_by_key(|&(_, &p)| p)
        .map(|(i, _)| i)
        .unwrap_or(0);
    // +2, not +1: pooled buffers rotate through roles (LIFO pool), so a
    // buffer that held a small role at the frontier peak may re-enter a
    // big role one iteration later and grow its capacity once more.
    let warmup = peak + 2;
    let steady = total.saturating_sub(warmup + 1);
    assert!(
        steady >= min_steady,
        "{label}/{kind}: only {steady} steady iterations \
         (total {total}, frontier peak at {peak}) — grow the graph"
    );

    // Measured run: identical deterministic schedule, counted.
    let mut s = build_strategy(kind, g.clone(), params);
    let mut ctx = ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer));
    s.init(&mut ctx, 0).expect("init");
    // The decision trace grows for the life of the run; its amortized
    // doubling is bookkeeping, not hot-path work — take it out of the
    // measurement by pre-sizing, exactly as a serving deployment would.
    ctx.metrics.decisions.reserve(total + 1);
    for i in 0..total {
        let (c0, b0) = snapshot();
        s.run_iteration(&mut ctx).expect("measured iteration");
        let (c1, b1) = snapshot();
        if i > warmup && !exempt[i] {
            assert_eq!(
                (c1 - c0, b1 - b0),
                (0, 0),
                "{label}/{kind}: iteration {i}/{total} (frontier {}) allocated \
                 {} times / {} bytes after warm-up",
                pending[i],
                c1 - c0,
                b1 - b0,
            );
        }
    }
    assert_eq!(s.pending(), 0, "{label}/{kind}: measured run must converge");
}

#[test]
fn steady_state_iterations_allocate_nothing() {
    // Long-diameter grid: ~60 BFS levels with a mid-run frontier peak —
    // a long, unambiguous steady-state window for every strategy.
    let grid = Arc::new(road_grid(32, 32, 9, 7).expect("road grid"));
    // Sparse ER (mean degree 3): a deep traversal with wide mid-run
    // frontiers, so HP's sub-iteration path and EP's exploded worklists
    // run warm for several post-peak iterations.
    let er = Arc::new(erdos_renyi(4096, 3 * 4096, 5, 11).expect("erdos-renyi"));

    for kind in StrategyKind::ALL {
        assert_zero_alloc_steady_state(kind, &grid, "grid32", 8);
    }
    for kind in StrategyKind::ALL {
        assert_zero_alloc_steady_state(kind, &er, "er4096", 1);
    }
    // The adaptive engine: steady (non-switching) iterations must be as
    // clean as the static strategies they execute as.
    assert_zero_alloc_steady_state(StrategyKind::AD, &grid, "grid32", 8);
    assert_zero_alloc_steady_state(StrategyKind::AD, &er, "er4096", 1);

    // The admission-controlled serving scheduler: once its machinery and
    // one full-size batch are warm, every further event-loop step —
    // arrivals, admissions, blocked drains, placements, batch launches
    // (QueryBatch::reset + run on a persistent engine) and completions —
    // allocates zero bytes.
    scheduler_steady_state_allocates_nothing(&er, false, 1, 0);
    // Same loop with a TraceSink attached: recording is an index write
    // into the pre-allocated ring, so observability must not cost the
    // steady state its zero-alloc contract.
    scheduler_steady_state_allocates_nothing(&er, true, 1, 0);
    // Worker threads: the counting allocator is process-wide (it tallies
    // every thread), and the dispatch barrier leaves workers idle
    // whenever `step` returns — so a zero delta across a step proves the
    // coordinator AND every worker allocated nothing: launch/report
    // messages ride pre-allocated mailbox slots and each worker
    // re-assembles its ExecCtx from persistent parts by swap. Two shards
    // on one worker, then true two-thread parallelism, with and without
    // tracing (per-shard rings are pre-allocated at attach).
    scheduler_steady_state_allocates_nothing(&er, false, 2, 1);
    scheduler_steady_state_allocates_nothing(&er, false, 2, 2);
    scheduler_steady_state_allocates_nothing(&er, true, 2, 2);
    // Fault injection in flight: aborts, requeues, retry-backoff drains,
    // quarantine/re-admit transitions and budget shrinks all ride the
    // same pre-allocated machinery, so an *active* fault plan must not
    // cost the steady state its zero-alloc contract either.
    scheduler_faulted_steady_state_allocates_nothing(&er);
}

/// The faulted twin of [`scheduler_steady_state_allocates_nothing`]: two
/// shards, two workers, a traced run, and a fault plan that stalls shard 0
/// on a geometric ladder (so outages land throughout the stream whatever
/// its virtual span), degrades shard 1's throughput for a stretch and
/// shrinks its memory budget. Every post-warm-up step — including the
/// steps that fire faults, abort running batches, requeue victims and
/// re-admit retries — must allocate zero bytes: the retry buffer, the
/// failed/expired vectors and the trace rings are all pre-sized at
/// construction.
fn scheduler_faulted_steady_state_allocates_nothing(g: &Arc<Csr>) {
    let count: u32 = 72;
    let arrivals: Vec<Arrival> = (0..count)
        .map(|i| Arrival {
            query: Query {
                id: i,
                algo: AlgoKind::Bfs,
                source: 0,
            },
            at_ps: (i as u64 + 1) * 10,
        })
        .collect();
    // Stalls at 1e5 << 2k ps, lifted at twice that: the windows tile
    // five decades of virtual time, so wherever the stream's span falls,
    // several outages interrupt running batches.
    let mut events = Vec::new();
    for k in 0..12u32 {
        let base = 100_000u64 << (2 * k);
        events.push(FaultEvent {
            at_ps: base,
            shard: 0,
            kind: FaultKind::Down { permanent: false },
        });
        events.push(FaultEvent {
            at_ps: 2 * base,
            shard: 0,
            kind: FaultKind::Up,
        });
    }
    events.push(FaultEvent {
        at_ps: 300_000,
        shard: 1,
        kind: FaultKind::Slow { factor: 3 },
    });
    events.push(FaultEvent {
        at_ps: 2_000_000_000,
        shard: 1,
        kind: FaultKind::Slow { factor: 1 },
    });
    events.push(FaultEvent {
        at_ps: 500_000,
        shard: 1,
        kind: FaultKind::Shrink { divisor: 2 },
    });
    let cfg = SchedulerConfig {
        serve: ServeConfig {
            strategy: StrategyKind::BS,
            devices: vec![DeviceSpec::k20c(); 2],
            max_batch: 4,
            ..Default::default()
        },
        queue_cap: 8,
        overflow: OverflowPolicy::Block,
        collect_distances: false,
        workers: 2,
        faults: Some(FaultPlan::from_events(events)),
        // Generous retry budget: the ladder can abort the same query more
        // than once, and this test is about allocations, not shedding.
        max_retries: 16,
        retry_backoff_ps: 1_000_000, // 1 µs: retries land inside the stream
        ..Default::default()
    };
    let cache = GraphCache::new();
    let mut sink = lonestar_lb::telemetry::TraceSink::with_capacity(1 << 14);
    let mut sched = Scheduler::new(g.clone(), arrivals, &cfg, &cache).expect("scheduler");
    sched.attach_trace(&mut sink);
    let mut steps = 0usize;
    let mut measured = 0usize;
    loop {
        let warm = sched.batches_launched() >= 4;
        let (c0, b0) = snapshot();
        let more = sched.step().expect("scheduler step");
        let (c1, b1) = snapshot();
        if warm && more {
            measured += 1;
            assert_eq!(
                (c1 - c0, b1 - b0),
                (0, 0),
                "faulted scheduler step {steps} allocated {} times / {} bytes after warm-up",
                c1 - c0,
                b1 - b0,
            );
        }
        steps += 1;
        assert!(steps < 20_000, "faulted scheduler failed to drain");
        if !more {
            break;
        }
    }
    assert!(
        measured >= 8,
        "only {measured} steady faulted steps measured — grow the stream"
    );
    let report = sched.finish();
    use lonestar_lb::telemetry::TraceEventKind;
    assert_eq!(report.arrived, count as u64);
    // Conservation still holds under Block + faults: nothing is dropped,
    // but retry exhaustion may fail a query.
    assert_eq!(
        report.arrived,
        report.served() as u64
            + report.dropped.len() as u64
            + report.deadline_expired.len() as u64
            + report.failed.len() as u64,
    );
    assert!(report.dropped.is_empty(), "block policy sheds nothing");
    assert!(
        report.requeued > 0,
        "the stall ladder must abort at least one running batch"
    );
    assert!(sink.kind_count(TraceEventKind::FaultInject) > 0);
    assert!(sink.kind_count(TraceEventKind::ShardDown) > 0);
    assert!(sink.kind_count(TraceEventKind::Requeue) >= report.requeued);
    assert!(
        report.shards[0].downtime_ps > 0,
        "quarantine windows must be attributed to shard 0"
    );
}

/// Drive the scheduler over a fixed burst-arrival stream (identical
/// sources, so every batch is the same shape) and assert a 0-byte
/// allocation delta for every step after the warm-up horizon. Distance
/// collection is off: cloning a result array is inherently an allocation
/// and belongs to result *extraction*, not the scheduling loop. With
/// `traced`, a pre-allocated [`lonestar_lb::telemetry::TraceSink`] rides
/// along and the same zero-delta assertions must hold.
///
/// `shards` grows the homogeneous pool and `workers` picks the thread
/// count (0 = one per shard). The allocation counters are process-wide,
/// so the per-step zero delta covers every worker thread too — the
/// dispatch barrier guarantees workers are quiescent between steps,
/// making the snapshot pairs race-free.
fn scheduler_steady_state_allocates_nothing(
    g: &Arc<Csr>,
    traced: bool,
    shards: usize,
    workers: usize,
) {
    let count: u32 = if shards > 1 { 72 } else { 40 };
    let arrivals: Vec<Arrival> = (0..count)
        .map(|i| Arrival {
            query: Query {
                id: i,
                algo: AlgoKind::Bfs,
                source: 0,
            },
            at_ps: (i as u64 + 1) * 10,
        })
        .collect();
    let cfg = SchedulerConfig {
        serve: ServeConfig {
            strategy: StrategyKind::BS,
            devices: vec![DeviceSpec::k20c(); shards],
            max_batch: 4,
            ..Default::default()
        },
        queue_cap: 8,
        // Block: nothing is shed, so the stream sustains many identical
        // batches — a long measured window.
        overflow: OverflowPolicy::Block,
        collect_distances: false,
        workers,
        ..Default::default()
    };
    let cache = GraphCache::new();
    // Declared before the scheduler so the sink outlives its borrow; its
    // one allocation happens here, before any measured step.
    let mut sink = lonestar_lb::telemetry::TraceSink::with_capacity(1 << 14);
    let mut sched = Scheduler::new(g.clone(), arrivals, &cfg, &cache).expect("scheduler");
    assert_eq!(
        sched.worker_threads(),
        if workers == 0 { shards } else { workers.min(shards) }
    );
    if traced {
        sched.attach_trace(&mut sink);
    }
    let mut steps = 0usize;
    let mut measured = 0usize;
    loop {
        // Warm once every shard has launched a full-size batch: the first
        // launch per shard is narrow (the burst is still arriving), the
        // next is full-size and grows that shard's buffers to their
        // high-water capacity — hence 2 batches per shard.
        let warm = sched.batches_launched() >= 2 * shards as u64;
        let (c0, b0) = snapshot();
        let more = sched.step().expect("scheduler step");
        let (c1, b1) = snapshot();
        if warm && more {
            measured += 1;
            assert_eq!(
                (c1 - c0, b1 - b0),
                (0, 0),
                "scheduler step {steps} allocated {} times / {} bytes after warm-up",
                c1 - c0,
                b1 - b0,
            );
        }
        steps += 1;
        assert!(steps < 10_000, "scheduler failed to drain");
        if !more {
            break;
        }
    }
    assert!(
        measured >= 8,
        "only {measured} steady scheduler steps measured — grow the stream"
    );
    let report = sched.finish();
    assert_eq!(report.arrived, count as u64);
    assert_eq!(report.served() as u64, count as u64, "block policy serves all");
    assert!(report.dropped.is_empty());
    assert!(report.batches >= 3);
    if traced {
        use lonestar_lb::telemetry::TraceEventKind;
        assert!(sink.recorded() > 0, "attached sink must capture the run");
        assert_eq!(sink.overwritten(), 0, "ring must not wrap at this scale");
        assert_eq!(sink.kind_count(TraceEventKind::Arrival), count as u64);
        assert_eq!(sink.kind_count(TraceEventKind::BatchLaunch), report.batches);
        assert_eq!(
            sink.kind_count(TraceEventKind::ShardBusy),
            report.batches,
            "one busy interval per completed batch"
        );
    }
}
