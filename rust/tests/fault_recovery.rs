//! Fault-injection & recovery suite for the serving scheduler.
//!
//! Faults are simulation events on the virtual clock, so every guarantee
//! the healthy scheduler makes must survive them:
//!
//! * **Conservation** — every arrival is accounted for exactly once:
//!   `arrived == served + dropped + deadline_expired + failed`, and no
//!   query id appears in two ledgers. Checked per fault kind (stall,
//!   kill, slow, shrink) and for the seeded synthetic stream.
//! * **Correctness of survivors** — queries served *through* outages,
//!   aborts and retries replay bit-identically through the single-query
//!   engine (the same differential oracle as `serving_parity.rs`).
//! * **Determinism** — same seed + same fault plan ⇒ byte-identical
//!   report JSON, Chrome trace, profile JSON and Prometheus exposition
//!   for `workers ∈ {1, 2, one-per-shard}`.
//! * **Termination** — killing every shard under `OverflowPolicy::Block`
//!   must not spin the event loop: the no-progress detector fails the
//!   stranded remainder cleanly and the run returns.

use lonestar_lb::arena::GraphCache;
use lonestar_lb::graph::generators::{rmat, RmatParams};
use lonestar_lb::graph::Csr;
use lonestar_lb::serving::{
    serve_stream, serve_stream_traced, synthetic_arrivals, FaultEvent, FaultKind, FaultPlan,
    OverflowPolicy, SchedulerConfig, ScheduleReport, ServeConfig,
};
use lonestar_lb::sim::DeviceSpec;
use lonestar_lb::strategies::{StrategyKind, StrategyParams};
use lonestar_lb::telemetry::{chrome_trace, profile_report, TraceEventKind, TraceSink};
use std::collections::HashSet;
use std::sync::Arc;

const MS: u64 = 1_000_000_000; // ps per virtual millisecond

fn graph() -> Arc<Csr> {
    Arc::new(rmat(9, 4096, RmatParams::default(), 42).unwrap())
}

fn pool() -> Vec<DeviceSpec> {
    vec![DeviceSpec::k20c(), DeviceSpec::k40(), DeviceSpec::gtx680()]
}

fn base_cfg(faults: Option<FaultPlan>) -> SchedulerConfig {
    SchedulerConfig {
        serve: ServeConfig {
            devices: pool(),
            max_batch: 8,
            ..Default::default()
        },
        queue_cap: 24,
        overflow: OverflowPolicy::Block,
        faults,
        ..Default::default()
    }
}

/// Every arrival lands in exactly one ledger, and the ledgers are
/// disjoint by query id.
fn assert_conservation(report: &ScheduleReport, label: &str) {
    assert_eq!(
        report.arrived,
        report.served() as u64
            + report.dropped.len() as u64
            + report.deadline_expired.len() as u64
            + report.failed.len() as u64,
        "{label}: conservation identity violated"
    );
    let mut seen = HashSet::new();
    for o in &report.outcomes {
        assert!(seen.insert(o.query.id), "{label}: served twice: {}", o.query.id);
    }
    for q in report
        .dropped
        .iter()
        .chain(&report.deadline_expired)
        .chain(&report.failed)
    {
        assert!(seen.insert(q.id), "{label}: double-ledgered id {}", q.id);
    }
}

/// Run one faulted stream and conservation-check it.
fn run_conserved(
    g: &Arc<Csr>,
    cfg: &SchedulerConfig,
    queries: usize,
    gap_ps: u64,
    seed: u64,
    label: &str,
) -> ScheduleReport {
    let arrivals = synthetic_arrivals(g, queries, 0.5, gap_ps, seed);
    let report = serve_stream(g, arrivals, cfg, &GraphCache::new()).unwrap();
    assert_eq!(report.arrived, queries as u64, "{label}: arrivals consumed");
    assert_conservation(&report, label);
    report
}

#[test]
fn conservation_holds_under_every_fault_kind() {
    let g = graph();

    // Transient stall mid-stream: aborted batches requeue and are
    // eventually served — Block sheds nothing and the deadline is off,
    // so everything must come back.
    let stall = FaultPlan::from_events(vec![
        FaultEvent { at_ps: MS / 2, shard: 0, kind: FaultKind::Down { permanent: false } },
        FaultEvent { at_ps: 3 * MS, shard: 0, kind: FaultKind::Up },
    ]);
    let r = run_conserved(&g, &base_cfg(Some(stall)), 48, 60_000, 7, "stall");
    assert_eq!(r.served() as u64, r.arrived, "stall: transient outage loses nothing");
    assert!(r.shards[0].downtime_ps > 0, "stall: downtime attributed");

    // Permanent kill: the survivors carry the load; nothing is lost as
    // long as one shard lives.
    let kill = FaultPlan::from_events(vec![FaultEvent {
        at_ps: MS / 2,
        shard: 1,
        kind: FaultKind::Down { permanent: true },
    }]);
    let r = run_conserved(&g, &base_cfg(Some(kill)), 48, 60_000, 7, "kill");
    assert_eq!(r.served() as u64, r.arrived, "kill: two survivors absorb the pool");
    assert!(r.shards[1].downtime_ps > 0, "kill: downtime runs to the wall");
    assert!(
        r.shards[1].availability(r.wall_ps) < 1.0,
        "kill: availability reflects the outage"
    );

    // Throughput degradation: no capacity is lost, only time — served
    // must stay complete.
    let slow = FaultPlan::from_events(vec![FaultEvent {
        at_ps: MS / 4,
        shard: 2,
        kind: FaultKind::Slow { factor: 5 },
    }]);
    let r = run_conserved(&g, &base_cfg(Some(slow)), 48, 60_000, 7, "slow");
    assert_eq!(r.served() as u64, r.arrived, "slow: degraded shard still serves");

    // Budget shrink to nothing with no restore and a tight retry budget:
    // batches on the starved shard OOM, requeue, exhaust and fail — but
    // the ledgers still balance and the run terminates.
    let shrink = FaultPlan::from_events(vec![FaultEvent {
        at_ps: 0,
        shard: 0,
        kind: FaultKind::Shrink { divisor: u64::MAX },
    }]);
    let mut cfg = base_cfg(Some(shrink));
    cfg.max_retries = 2;
    cfg.retry_backoff_ps = MS / 10;
    let r = run_conserved(&g, &cfg, 48, 60_000, 7, "shrink");
    assert_eq!(
        r.served() + r.failed.len(),
        r.arrived as usize,
        "shrink: every query either served elsewhere or failed after retries"
    );

    // The seeded synthetic stream (the `random:` spec clause): whatever
    // mix it draws, the identity holds and the run drains.
    for seed in [3u64, 1911] {
        let plan = FaultPlan::synthetic(3, 0.5, 30.0, seed);
        assert!(!plan.is_empty(), "synthetic plan at this rate is non-trivial");
        let mut cfg = base_cfg(Some(plan));
        cfg.deadline_ps = 50 * MS;
        run_conserved(&g, &cfg, 64, 60_000, seed, "synthetic");
    }
}

#[test]
fn survivors_replay_bit_identically_through_the_single_query_engine() {
    let g = graph();
    let plan = FaultPlan::from_events(vec![
        FaultEvent { at_ps: MS / 2, shard: 0, kind: FaultKind::Down { permanent: false } },
        FaultEvent { at_ps: 2 * MS, shard: 0, kind: FaultKind::Up },
        FaultEvent { at_ps: MS, shard: 1, kind: FaultKind::Slow { factor: 3 } },
        FaultEvent { at_ps: 3 * MS / 2, shard: 2, kind: FaultKind::Down { permanent: true } },
    ]);
    let mut cfg = base_cfg(Some(plan));
    cfg.collect_distances = true;
    let report = run_conserved(&g, &cfg, 48, 60_000, 11, "replay");
    assert!(report.served() > 0, "replay: something must survive to check");
    // The same oracle as `--verify`: per shard, re-run every served query
    // through the single-query engine and compare distance arrays.
    let params = StrategyParams::default();
    for shard in &report.shards {
        lonestar_lb::serving::replay_single(
            &g,
            &shard.queries,
            StrategyKind::AD,
            &params,
            &shard.dists,
        )
        .expect("faulted survivors must replay bit-identically");
    }
}

/// Every export surface of one faulted seeded run, as bytes.
struct RunArtifacts {
    report_json: String,
    trace: String,
    profile: String,
    prometheus: String,
}

fn run_artifacts(g: &Arc<Csr>, seed: u64, workers: usize) -> RunArtifacts {
    let plan = FaultPlan::synthetic(3, 0.15, 30.0, seed);
    let cfg = SchedulerConfig {
        serve: ServeConfig {
            devices: pool(),
            max_batch: 12,
            ..Default::default()
        },
        queue_cap: 24,
        overflow: OverflowPolicy::Block,
        collect_distances: true,
        workers,
        faults: Some(plan),
        deadline_ps: 40 * MS,
        max_retries: 3,
        retry_backoff_ps: MS / 2,
    };
    let arrivals = synthetic_arrivals(g, 72, 0.5, 60_000, seed);
    let shard_ppc: Vec<u64> = cfg.serve.devices.iter().map(|d| d.ps_per_cycle()).collect();
    let mut sink = TraceSink::with_capacity(1 << 14);
    let report =
        serve_stream_traced(g, arrivals, &cfg, &GraphCache::new(), Some(&mut sink)).unwrap();
    assert_conservation(&report, &format!("artifacts seed={seed} workers={workers}"));
    RunArtifacts {
        report_json: report.to_json().to_string(),
        trace: chrome_trace(&sink, &["k20c", "k40", "gtx680"]),
        profile: profile_report(&sink, &shard_ppc).to_string(),
        prometheus: report.prometheus(Some(&sink)),
    }
}

#[test]
fn faulted_exports_are_byte_identical_across_worker_counts() {
    let g = graph();
    for seed in [3u64, 1911] {
        let baseline = run_artifacts(&g, seed, 1);
        for workers in [2usize, 3] {
            let par = run_artifacts(&g, seed, workers);
            let label = format!("seed={seed} workers={workers}");
            assert_eq!(baseline.report_json, par.report_json, "{label}: report");
            assert_eq!(baseline.trace, par.trace, "{label}: chrome trace");
            assert_eq!(baseline.profile, par.profile, "{label}: profile");
            assert_eq!(baseline.prometheus, par.prometheus, "{label}: prometheus");
        }
    }
}

#[test]
fn killing_every_shard_under_block_fails_the_remainder_instead_of_spinning() {
    // The regression this pins: before the no-progress detector, a Block
    // queue with zero live shards had no future event to advance the
    // clock — `serve_stream` span forever. Now the stranded remainder is
    // failed cleanly and the call returns.
    let g = graph();
    let plan = FaultPlan::from_events(vec![
        FaultEvent { at_ps: MS / 4, shard: 0, kind: FaultKind::Down { permanent: true } },
        FaultEvent { at_ps: MS / 4, shard: 1, kind: FaultKind::Down { permanent: true } },
        FaultEvent { at_ps: MS / 4, shard: 2, kind: FaultKind::Down { permanent: true } },
    ]);
    let report = run_conserved(&g, &base_cfg(Some(plan)), 48, 60_000, 5, "pool-death");
    assert!(
        !report.failed.is_empty(),
        "pool-death: the stranded remainder must be failed, not spun on"
    );
    assert!(
        report.served() < 48,
        "pool-death: a quarter-millisecond pool cannot serve the whole stream"
    );
    for s in &report.shards {
        assert!(s.downtime_ps > 0, "pool-death: every shard logs downtime");
        assert!(s.availability(report.wall_ps) < 1.0);
    }
}

#[test]
fn deadlines_shed_queries_stranded_by_an_outage() {
    let g = graph();
    // One shard, one long outage: whatever is waiting when the shard
    // goes dark ages past the deadline and must be shed as
    // `deadline_expired` — not served late, not spun on.
    let plan = FaultPlan::from_events(vec![
        FaultEvent { at_ps: MS / 2, shard: 0, kind: FaultKind::Down { permanent: false } },
        FaultEvent { at_ps: 60 * MS, shard: 0, kind: FaultKind::Up },
    ]);
    let cfg = SchedulerConfig {
        serve: ServeConfig {
            devices: vec![DeviceSpec::k20c()],
            max_batch: 4,
            ..Default::default()
        },
        queue_cap: 64,
        overflow: OverflowPolicy::Block,
        faults: Some(plan),
        deadline_ps: 5 * MS,
        ..Default::default()
    };
    let report = run_conserved(&g, &cfg, 32, 60_000, 13, "deadline");
    assert!(
        !report.deadline_expired.is_empty(),
        "deadline: a 60 ms outage against a 5 ms deadline must shed"
    );
    // Everything shed was genuinely late: the deadline ledger is only
    // reachable past `deadline_ps`, so the wall covers the outage.
    assert!(report.wall_ps >= 5 * MS);
}

#[test]
fn shrunken_budget_recovers_once_restored() {
    let g = graph();
    // Single shard: shrink the budget to one byte early, restore it at
    // 8 ms. Batches launched in between OOM and requeue; exponential
    // backoff walks the retries past the restore point, after which they
    // succeed — so the stream still serves *everything*, at a latency
    // cost visible in `retries`.
    let plan = FaultPlan::from_events(vec![
        FaultEvent { at_ps: MS / 4, shard: 0, kind: FaultKind::Shrink { divisor: u64::MAX } },
        FaultEvent { at_ps: 8 * MS, shard: 0, kind: FaultKind::Shrink { divisor: 1 } },
    ]);
    let cfg = SchedulerConfig {
        serve: ServeConfig {
            devices: vec![DeviceSpec::k20c()],
            max_batch: 4,
            ..Default::default()
        },
        queue_cap: 64,
        overflow: OverflowPolicy::Block,
        faults: Some(plan),
        max_retries: 12,
        retry_backoff_ps: MS,
        ..Default::default()
    };
    let report = run_conserved(&g, &cfg, 24, 60_000, 17, "shrink-restore");
    assert_eq!(
        report.served() as u64,
        report.arrived,
        "shrink-restore: every query must eventually be served"
    );
    assert!(
        report.requeued > 0 && report.retries > 0,
        "shrink-restore: the starved window must actually requeue work \
         (requeued {}, retries {})",
        report.requeued,
        report.retries,
    );
}

#[test]
fn adaptive_strategy_survives_a_shrunken_budget() {
    let g = graph();
    // AD under a quartered budget on every shard: the adaptive engine
    // keeps picking strategies that fit, so a *moderate* shrink costs
    // nothing — served stays complete and the ledgers balance. (The
    // starvation extreme is covered by `shrunken_budget_recovers_...`.)
    let plan = FaultPlan::from_events(
        (0..3)
            .map(|shard| FaultEvent {
                at_ps: MS / 4,
                shard,
                kind: FaultKind::Shrink { divisor: 4 },
            })
            .collect(),
    );
    let mut cfg = base_cfg(Some(plan));
    cfg.serve.strategy = StrategyKind::AD;
    cfg.serve.enforce_budget = true;
    let report = run_conserved(&g, &cfg, 48, 60_000, 19, "ad-shrink");
    assert_eq!(
        report.served() as u64,
        report.arrived,
        "ad-shrink: AD must keep serving under the shrunken budget"
    );
}

#[test]
fn fault_events_land_in_the_trace_with_their_payloads() {
    let g = graph();
    let plan = FaultPlan::from_events(vec![
        FaultEvent { at_ps: MS / 2, shard: 0, kind: FaultKind::Down { permanent: false } },
        FaultEvent { at_ps: 2 * MS, shard: 0, kind: FaultKind::Up },
        FaultEvent { at_ps: MS, shard: 1, kind: FaultKind::Slow { factor: 3 } },
    ]);
    let mut cfg = base_cfg(Some(plan));
    cfg.workers = 1;
    let arrivals = synthetic_arrivals(&g, 48, 0.5, 60_000, 23);
    let mut sink = TraceSink::with_capacity(1 << 14);
    let report =
        serve_stream_traced(&g, arrivals, &cfg, &GraphCache::new(), Some(&mut sink)).unwrap();
    assert_conservation(&report, "trace");
    assert_eq!(sink.kind_count(TraceEventKind::FaultInject), 3);
    assert_eq!(sink.kind_count(TraceEventKind::ShardDown), 1);
    assert_eq!(sink.kind_count(TraceEventKind::ShardUp), 1);
    assert_eq!(
        sink.kind_count(TraceEventKind::Retry),
        report.retries,
        "one Retry event per re-admission"
    );
    assert!(
        sink.kind_count(TraceEventKind::Requeue) >= report.requeued,
        "a Requeue event per buffered attempt (exhaustions add more)"
    );
    // The rendered Chrome trace names the new kinds.
    let trace = chrome_trace(&sink, &["k20c", "k40", "gtx680"]);
    for label in ["fault-inject", "shard-down", "shard-up"] {
        assert!(trace.contains(label), "chrome trace must carry {label:?} events");
    }
}
