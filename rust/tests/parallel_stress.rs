//! Coordinator/worker handshake stress tests (the no-new-deps stand-in
//! for a loom-style interleaving exploration): hammer the launch →
//! barrier → fold protocol with many small batches across repeated runs
//! and assert the invariants an interleaving bug would break —
//!
//! * **no lost batch**: every admitted query is served exactly once
//!   (conservation: arrived == served + dropped at drain);
//! * **no double-retire**: no query id appears in two outcomes, and the
//!   per-shard `served`/`dists` stay aligned;
//! * **clean shutdown**: dropping a scheduler mid-run — queue drained or
//!   not, workers mid-batch or idle — joins every worker thread without
//!   hanging or panicking.
//!
//! The heavy variant (`--ignored`) runs the same protocol long enough to
//! give the OS scheduler a real chance to produce novel interleavings;
//! CI runs the modest variant on every push.

use lonestar_lb::arena::GraphCache;
use lonestar_lb::graph::generators::erdos_renyi;
use lonestar_lb::graph::Csr;
use lonestar_lb::serving::{
    serve_stream, synthetic_arrivals, OverflowPolicy, Scheduler, SchedulerConfig, ServeConfig,
};
use lonestar_lb::sim::DeviceSpec;
use std::collections::HashSet;
use std::sync::Arc;

fn cfg(workers: usize, overflow: OverflowPolicy) -> SchedulerConfig {
    SchedulerConfig {
        serve: ServeConfig {
            devices: vec![DeviceSpec::k20c(), DeviceSpec::k40(), DeviceSpec::gtx680()],
            // Tiny batches => many launch/report round-trips: the
            // handshake, not the compute, dominates.
            max_batch: 2,
            ..Default::default()
        },
        queue_cap: 6,
        overflow,
        collect_distances: false,
        workers,
        ..Default::default()
    }
}

/// One full run; asserts conservation and exactly-once service.
fn run_and_check(g: &Arc<Csr>, queries: usize, seed: u64, workers: usize, overflow: OverflowPolicy) {
    let arrivals = synthetic_arrivals(g, queries, 0.5, 20_000, seed);
    let report = serve_stream(g, arrivals, &cfg(workers, overflow), &GraphCache::new()).unwrap();
    assert_eq!(report.arrived, queries as u64, "every arrival consumed");
    assert_eq!(
        report.arrived,
        report.served() as u64 + report.dropped.len() as u64,
        "no lost batch: served + dropped == arrived"
    );
    let mut seen = HashSet::with_capacity(report.served());
    for o in &report.outcomes {
        assert!(
            seen.insert(o.query.id),
            "query {} served twice (double retire)",
            o.query.id
        );
    }
    for q in &report.dropped {
        assert!(!seen.contains(&q.id), "query {} both dropped and served", q.id);
    }
    // Shard-level bookkeeping agrees with the outcome list.
    let per_shard: usize = report.shards.iter().map(|s| s.queries.len()).sum();
    assert_eq!(per_shard, report.served(), "shard rosters cover every outcome");
    if overflow == OverflowPolicy::Block {
        assert!(report.dropped.is_empty(), "block never sheds");
    }
}

#[test]
fn handshake_stress_modest() {
    let g = Arc::new(erdos_renyi(256, 1024, 7, 3).unwrap());
    for round in 0..4u64 {
        for workers in [1usize, 2, 3] {
            run_and_check(&g, 60, 100 + round, workers, OverflowPolicy::Drop);
            run_and_check(&g, 60, 200 + round, workers, OverflowPolicy::Block);
        }
    }
}

/// The long soak: run `cargo test -- --ignored` (or the nightly CI job)
/// to explore far more OS-level interleavings than the modest variant.
#[test]
#[ignore = "long soak; exercised by the nightly thread-sanitizer job"]
fn handshake_stress_heavy() {
    let g = Arc::new(erdos_renyi(512, 2048, 7, 3).unwrap());
    for round in 0..40u64 {
        for workers in [2usize, 3] {
            run_and_check(&g, 200, 1_000 + round, workers, OverflowPolicy::Drop);
            run_and_check(&g, 200, 2_000 + round, workers, OverflowPolicy::Block);
        }
    }
}

/// Dropping the scheduler without `finish` — mid-stream, workers idle at
/// the barrier — must shut the pool down cleanly (send shutdown, join
/// all). A deadlock here would hang the test harness, which is the
/// assertion.
#[test]
fn drop_without_finish_shuts_down_cleanly() {
    let g = Arc::new(erdos_renyi(256, 1024, 7, 3).unwrap());
    for steps_before_drop in [0usize, 1, 3, 7] {
        let arrivals = synthetic_arrivals(&g, 30, 0.5, 20_000, 99);
        let config = cfg(2, OverflowPolicy::Block);
        let mut sched = Scheduler::new(g.clone(), arrivals, &config, &GraphCache::new()).unwrap();
        for _ in 0..steps_before_drop {
            if !sched.step().unwrap() {
                break;
            }
        }
        drop(sched);
    }
}

/// The drain edge: the queue empties while workers are mid-batch (the
/// final dispatch round), and `finish` joins everyone gracefully.
#[test]
fn drain_while_workers_busy_then_finish() {
    let g = Arc::new(erdos_renyi(256, 1024, 7, 3).unwrap());
    for workers in [1usize, 2, 3] {
        let arrivals = synthetic_arrivals(&g, 45, 0.5, 20_000, 17);
        let config = cfg(workers, OverflowPolicy::Block);
        let mut sched = Scheduler::new(g.clone(), arrivals, &config, &GraphCache::new()).unwrap();
        while sched.step().unwrap() {}
        let report = sched.finish();
        assert_eq!(report.served() as u64, report.arrived);
    }
}
