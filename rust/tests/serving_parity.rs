//! The serving layer's differential test suite: batched multi-query runs
//! must produce distance arrays **bit-identical** to replaying every query
//! through the existing single-query engine — for BFS and SSSP, across all
//! `StrategyKind`s (AD included, under every policy), and across 1/2/4
//! device shards. Random graphs and random source sets come from
//! `util::rng` with fixed seeds, so every failure reproduces exactly.

use lonestar_lb::adaptive::AdaptivePolicyKind;
use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::graph::generators::{erdos_renyi, rmat, road_grid, RmatParams};
use lonestar_lb::graph::{Csr, Graph};
use lonestar_lb::serving::{
    replay_single, serve, synthetic_queries, Query, ServeConfig,
};
use lonestar_lb::strategies::{StrategyKind, StrategyParams};
use lonestar_lb::util::Rng;
use std::sync::Arc;

/// The differential graph pool: one skewed (RMAT), one uniform
/// (Erdős–Rényi), one road-like grid.
fn graphs() -> Vec<(&'static str, Arc<Csr>)> {
    vec![
        (
            "rmat",
            Arc::new(rmat(8, 2048, RmatParams::default(), 31).unwrap()),
        ),
        ("er", Arc::new(erdos_renyi(300, 1200, 20, 32).unwrap())),
        ("road", Arc::new(road_grid(16, 16, 9, 33).unwrap())),
    ]
}

/// Random source set over the non-isolated nodes (fixed seed).
fn random_queries(g: &Csr, count: usize, algo: AlgoKind, seed: u64) -> Vec<Query> {
    let mut rng = Rng::seed_from_u64(seed);
    let candidates: Vec<u32> = (0..g.num_nodes() as u32)
        .filter(|&u| g.degree(u) > 0)
        .collect();
    (0..count as u32)
        .map(|id| Query {
            id,
            algo,
            source: candidates[rng.gen_index(candidates.len())],
        })
        .collect()
}

/// Serve `queries` and assert bit-identical distances vs. the single-query
/// engine, via the baked-in replay oracle.
fn assert_parity(
    g: &Arc<Csr>,
    queries: &[Query],
    strategy: StrategyKind,
    params: StrategyParams,
    shards: usize,
    label: &str,
) {
    let cfg = ServeConfig {
        strategy,
        params: params.clone(),
        shards,
        ..Default::default()
    };
    let report = serve(g, queries, &cfg)
        .unwrap_or_else(|e| panic!("{label}: serve failed: {e}"));
    assert_eq!(report.query_count(), queries.len(), "{label}: lost queries");
    for shard in &report.shards {
        replay_single(g, &shard.queries, strategy, &params, &shard.dists)
            .unwrap_or_else(|e| panic!("{label}: {e}"));
    }
}

#[test]
fn batched_matches_single_runs_across_all_strategies() {
    for (name, g) in graphs() {
        for algo in [AlgoKind::Bfs, AlgoKind::Sssp] {
            let queries = random_queries(&g, 4, algo, 0xD1F + name.len() as u64);
            for strategy in StrategyKind::ALL_WITH_ADAPTIVE {
                assert_parity(
                    &g,
                    &queries,
                    strategy,
                    StrategyParams::default(),
                    1,
                    &format!("{name}/{algo:?}/{strategy}"),
                );
            }
        }
    }
}

#[test]
fn batched_ad_matches_under_every_policy() {
    // Round-robin forces a migration-heavy decision trace; the heuristic
    // and cost-model policies cover the production paths.
    for (name, g) in graphs() {
        let queries = random_queries(&g, 5, AlgoKind::Sssp, 0xAD0 + name.len() as u64);
        for policy in [
            AdaptivePolicyKind::CostModel,
            AdaptivePolicyKind::Heuristic,
            AdaptivePolicyKind::RoundRobin,
        ] {
            let params = StrategyParams {
                adaptive_policy: policy,
                ..Default::default()
            };
            assert_parity(
                &g,
                &queries,
                StrategyKind::AD,
                params,
                1,
                &format!("{name}/AD/{policy:?}"),
            );
        }
    }
}

#[test]
fn shard_counts_never_change_results_any_strategy() {
    // The full acceptance matrix: every strategy (AD included) across
    // 1/2/4 shards, BFS and SSSP alternating by graph to bound runtime.
    for (gi, (name, g)) in graphs().into_iter().enumerate() {
        let algo = if gi % 2 == 0 { AlgoKind::Sssp } else { AlgoKind::Bfs };
        let queries = random_queries(&g, 6, algo, 0x54A2D + name.len() as u64);
        for shards in [1usize, 2, 4] {
            for strategy in StrategyKind::ALL_WITH_ADAPTIVE {
                assert_parity(
                    &g,
                    &queries,
                    strategy,
                    StrategyParams::default(),
                    shards,
                    &format!("{name}/{algo:?}/{strategy}/{shards}shards"),
                );
            }
        }
    }
}

#[test]
fn mixed_algo_batches_keep_queries_independent() {
    for (name, g) in graphs() {
        // Interleave BFS and SSSP from the same sources in one batch: the
        // per-query dist arrays must not bleed into each other.
        let mut queries = random_queries(&g, 3, AlgoKind::Bfs, 0x317 + name.len() as u64);
        let twins: Vec<Query> = queries
            .iter()
            .map(|q| Query {
                id: q.id + 100,
                algo: AlgoKind::Sssp,
                source: q.source,
            })
            .collect();
        queries.extend(twins);
        for shards in [1usize, 2] {
            assert_parity(
                &g,
                &queries,
                StrategyKind::AD,
                StrategyParams::default(),
                shards,
                &format!("{name}/mixed/{shards}shards"),
            );
        }
    }
}

#[test]
fn synthetic_driver_queries_are_servable_and_parity_holds() {
    // End-to-end over the CLI's own arrival driver.
    let pool = graphs();
    let (_, g) = &pool[0];
    let queries = synthetic_queries(g, 12, 0.5, 2026);
    assert_parity(
        g,
        &queries,
        StrategyKind::AD,
        StrategyParams::default(),
        2,
        "driver/AD/2shards",
    );
}

#[test]
fn batched_runs_are_deterministic() {
    let pool = graphs();
    let (_, g) = &pool[0];
    let queries = random_queries(g, 4, AlgoKind::Sssp, 77);
    let cfg = ServeConfig {
        shards: 2,
        ..Default::default()
    };
    let a = serve(g, &queries, &cfg).unwrap();
    let b = serve(g, &queries, &cfg).unwrap();
    for q in &queries {
        assert_eq!(a.dist_of(q.id), b.dist_of(q.id));
    }
    let (ta, tb) = (a.totals(), b.totals());
    assert_eq!(ta, tb, "metrics must reproduce run-to-run");
}
