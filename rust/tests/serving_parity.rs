//! The serving layer's differential test suite: batched multi-query runs
//! must produce distance arrays **bit-identical** to replaying every query
//! through the existing single-query engine — for BFS and SSSP, across all
//! `StrategyKind`s (AD included, under every policy), and across 1/2/4
//! device shards. Random graphs and random source sets come from
//! `util::rng` with fixed seeds, so every failure reproduces exactly.

use lonestar_lb::adaptive::AdaptivePolicyKind;
use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::arena::GraphCache;
use lonestar_lb::graph::generators::{erdos_renyi, rmat, road_grid, RmatParams};
use lonestar_lb::graph::{Csr, Graph};
use lonestar_lb::serving::{
    replay_single, serve, serve_stream, synthetic_arrivals, synthetic_queries, Query,
    SchedulerConfig, ServeConfig,
};
use lonestar_lb::sim::DeviceSpec;
use lonestar_lb::strategies::{StrategyKind, StrategyParams};
use lonestar_lb::util::Rng;
use std::sync::Arc;

/// The differential graph pool: one skewed (RMAT), one uniform
/// (Erdős–Rényi), one road-like grid.
fn graphs() -> Vec<(&'static str, Arc<Csr>)> {
    vec![
        (
            "rmat",
            Arc::new(rmat(8, 2048, RmatParams::default(), 31).unwrap()),
        ),
        ("er", Arc::new(erdos_renyi(300, 1200, 20, 32).unwrap())),
        ("road", Arc::new(road_grid(16, 16, 9, 33).unwrap())),
    ]
}

/// Random source set over the non-isolated nodes (fixed seed).
fn random_queries(g: &Csr, count: usize, algo: AlgoKind, seed: u64) -> Vec<Query> {
    let mut rng = Rng::seed_from_u64(seed);
    let candidates: Vec<u32> = (0..g.num_nodes() as u32)
        .filter(|&u| g.degree(u) > 0)
        .collect();
    (0..count as u32)
        .map(|id| Query {
            id,
            algo,
            source: candidates[rng.gen_index(candidates.len())],
        })
        .collect()
}

/// Serve `queries` and assert bit-identical distances vs. the single-query
/// engine, via the baked-in replay oracle.
fn assert_parity(
    g: &Arc<Csr>,
    queries: &[Query],
    strategy: StrategyKind,
    params: StrategyParams,
    shards: usize,
    label: &str,
) {
    let cfg = ServeConfig {
        strategy,
        params,
        ..ServeConfig::with_shards(shards)
    };
    assert_parity_cfg(g, queries, &cfg, label);
}

/// [`assert_parity`] with a caller-built config (heterogeneous pools,
/// raised `max_batch`).
fn assert_parity_cfg(g: &Arc<Csr>, queries: &[Query], cfg: &ServeConfig, label: &str) {
    let report = serve(g, queries, cfg)
        .unwrap_or_else(|e| panic!("{label}: serve failed: {e}"));
    assert_eq!(report.query_count(), queries.len(), "{label}: lost queries");
    for shard in &report.shards {
        replay_single(g, &shard.queries, cfg.strategy, &cfg.params, &shard.dists)
            .unwrap_or_else(|e| panic!("{label}: {e}"));
    }
}

#[test]
fn batched_matches_single_runs_across_all_strategies() {
    for (name, g) in graphs() {
        for algo in [AlgoKind::Bfs, AlgoKind::Sssp] {
            let queries = random_queries(&g, 4, algo, 0xD1F + name.len() as u64);
            for strategy in StrategyKind::ALL_WITH_ADAPTIVE {
                assert_parity(
                    &g,
                    &queries,
                    strategy,
                    StrategyParams::default(),
                    1,
                    &format!("{name}/{algo:?}/{strategy}"),
                );
            }
        }
    }
}

#[test]
fn batched_ad_matches_under_every_policy() {
    // Round-robin forces a migration-heavy decision trace; the heuristic
    // and cost-model policies cover the production paths.
    for (name, g) in graphs() {
        let queries = random_queries(&g, 5, AlgoKind::Sssp, 0xAD0 + name.len() as u64);
        for policy in [
            AdaptivePolicyKind::CostModel,
            AdaptivePolicyKind::Heuristic,
            AdaptivePolicyKind::RoundRobin,
        ] {
            let params = StrategyParams {
                adaptive_policy: policy,
                ..Default::default()
            };
            assert_parity(
                &g,
                &queries,
                StrategyKind::AD,
                params,
                1,
                &format!("{name}/AD/{policy:?}"),
            );
        }
    }
}

#[test]
fn shard_counts_never_change_results_any_strategy() {
    // The full acceptance matrix: every strategy (AD included) across
    // 1/2/4 shards, BFS and SSSP alternating by graph to bound runtime.
    for (gi, (name, g)) in graphs().into_iter().enumerate() {
        let algo = if gi % 2 == 0 { AlgoKind::Sssp } else { AlgoKind::Bfs };
        let queries = random_queries(&g, 6, algo, 0x54A2D + name.len() as u64);
        for shards in [1usize, 2, 4] {
            for strategy in StrategyKind::ALL_WITH_ADAPTIVE {
                assert_parity(
                    &g,
                    &queries,
                    strategy,
                    StrategyParams::default(),
                    shards,
                    &format!("{name}/{algo:?}/{strategy}/{shards}shards"),
                );
            }
        }
    }
}

#[test]
fn mixed_algo_batches_keep_queries_independent() {
    for (name, g) in graphs() {
        // Interleave BFS and SSSP from the same sources in one batch: the
        // per-query dist arrays must not bleed into each other.
        let mut queries = random_queries(&g, 3, AlgoKind::Bfs, 0x317 + name.len() as u64);
        let twins: Vec<Query> = queries
            .iter()
            .map(|q| Query {
                id: q.id + 100,
                algo: AlgoKind::Sssp,
                source: q.source,
            })
            .collect();
        queries.extend(twins);
        for shards in [1usize, 2] {
            assert_parity(
                &g,
                &queries,
                StrategyKind::AD,
                StrategyParams::default(),
                shards,
                &format!("{name}/mixed/{shards}shards"),
            );
        }
    }
}

#[test]
fn synthetic_driver_queries_are_servable_and_parity_holds() {
    // End-to-end over the CLI's own arrival driver.
    let pool = graphs();
    let (_, g) = &pool[0];
    let queries = synthetic_queries(g, 12, 0.5, 2026);
    assert_parity(
        g,
        &queries,
        StrategyKind::AD,
        StrategyParams::default(),
        2,
        "driver/AD/2shards",
    );
}

#[test]
fn batched_runs_are_deterministic() {
    let pool = graphs();
    let (_, g) = &pool[0];
    let queries = random_queries(g, 4, AlgoKind::Sssp, 77);
    let cfg = ServeConfig::with_shards(2);
    let a = serve(g, &queries, &cfg).unwrap();
    let b = serve(g, &queries, &cfg).unwrap();
    for q in &queries {
        assert_eq!(a.dist_of(q.id), b.dist_of(q.id));
    }
    let (ta, tb) = (a.totals(), b.totals());
    assert_eq!(ta, tb, "metrics must reproduce run-to-run");
}

#[test]
fn wide_batches_replay_bit_identically_across_all_strategies() {
    // 65–200 queries on ONE shard: the merged worklist's tag spills past
    // its first word (multi-word masks), and every strategy — AD included
    // — must still replay bit-identically, BFS and SSSP.
    let g = Arc::new(erdos_renyi(300, 1200, 20, 32).unwrap());
    for (count, algo) in [(70usize, AlgoKind::Bfs), (130, AlgoKind::Sssp)] {
        let queries = random_queries(&g, count, algo, 0xB16 + count as u64);
        for strategy in StrategyKind::ALL_WITH_ADAPTIVE {
            let cfg = ServeConfig {
                strategy,
                max_batch: 200,
                ..Default::default()
            };
            assert_parity_cfg(&g, &queries, &cfg, &format!("wide{count}/{algo:?}/{strategy}"));
        }
    }
}

#[test]
fn heterogeneous_shard_sets_replay_bit_identically() {
    // A mixed k20c/k40/gtx680 pool: placement is round-robin here (plain
    // serve), but each shard runs on its own device spec — distances must
    // not care, for every strategy, BFS and SSSP.
    let g = Arc::new(road_grid(16, 16, 9, 33).unwrap());
    let devices = vec![DeviceSpec::k20c(), DeviceSpec::k40(), DeviceSpec::gtx680()];
    for algo in [AlgoKind::Bfs, AlgoKind::Sssp] {
        let queries = random_queries(&g, 9, algo, 0x4E7 + algo as u64);
        for strategy in StrategyKind::ALL_WITH_ADAPTIVE {
            let cfg = ServeConfig {
                strategy,
                devices: devices.clone(),
                ..Default::default()
            };
            assert_parity_cfg(&g, &queries, &cfg, &format!("hetero/{algo:?}/{strategy}"));
        }
    }
}

#[test]
fn scheduler_150_queries_heterogeneous_with_forced_drops() {
    // The acceptance scenario: a 150-query continuous stream over a
    // heterogeneous pool with a queue small enough to force drops. Served
    // queries replay bit-identically; dropped ones are excluded from the
    // comparison but stay counted in the report.
    let g = Arc::new(rmat(8, 2048, RmatParams::default(), 31).unwrap());
    let cfg = SchedulerConfig {
        serve: ServeConfig {
            devices: vec![DeviceSpec::k20c(), DeviceSpec::gtx680()],
            // Note: with queue_cap 8 the queue bounds batch width, so this
            // run exercises *drops*, not wide batches — >64-query batches
            // are pinned by `wide_batches_replay_bit_identically_...` and
            // the scheduler's own `scheduler_forms_batches_past_64_queries`.
            max_batch: 96,
            ..Default::default()
        },
        queue_cap: 8,
        ..Default::default()
    };
    // Mean gap 0.002 ms ⇒ ~500 q/ms: far beyond service capacity.
    let arrivals = synthetic_arrivals(&g, 150, 0.5, 2_000_000, 2026);
    let report = serve_stream(&g, arrivals, &cfg, &GraphCache::new()).unwrap();
    assert_eq!(report.arrived, 150);
    assert!(
        !report.dropped.is_empty(),
        "an 8-deep queue at 500 q/ms must shed load"
    );
    assert_eq!(
        report.arrived,
        report.admitted + report.dropped.len() as u64,
        "conservation: arrived == admitted + dropped"
    );
    assert_eq!(report.admitted, report.served() as u64, "admitted == served at drain");
    // Bit-identical replay of every *served* query, per shard.
    for shard in &report.shards {
        replay_single(
            &g,
            &shard.queries,
            StrategyKind::AD,
            &cfg.serve.params,
            &shard.dists,
        )
        .unwrap_or_else(|e| panic!("scheduler shard {}: {e}", shard.shard));
    }
    // Dropped queries were never answered.
    for q in &report.dropped {
        assert!(report.dist_of(q.id).is_none(), "dropped query {} has results", q.id);
    }
    // Per-shard ms figures use each shard's own device spec.
    for shard in &report.shards {
        let own = shard.device.cycles_to_ms(shard.metrics.total_cycles());
        assert!((shard.total_ms() - own).abs() < 1e-12, "shard {}", shard.shard);
    }
    assert!(
        (report.total_ms()
            - report
                .shards
                .iter()
                .map(|s| s.total_ms())
                .sum::<f64>())
        .abs()
            < 1e-9
    );
}
