//! Backend parity: the XLA (AOT Pallas/JAX artifact) relaxer must agree
//! bit-for-bit with the native Rust relaxer — same distances, same update
//! counts, same simulated cycles (scheduling is backend-independent).
//!
//! Skipped gracefully when `make artifacts` has not run.

use lonestar_lb::algorithms::{AlgoKind, NativeRelaxer, Relaxer};
use lonestar_lb::coordinator::engine::Backend;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::{erdos_renyi, rmat, road_grid, RmatParams};
use lonestar_lb::runtime::XlaRelaxer;
use lonestar_lb::strategies::StrategyKind;
use lonestar_lb::util::Rng;
use lonestar_lb::INF;
use std::sync::Arc;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("LONESTAR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {dir}/ (run `make artifacts`)");
        None
    }
}

#[test]
fn relaxer_candidates_bitwise_equal() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaRelaxer::load(&dir).unwrap();
    let mut native = NativeRelaxer;
    let mut rng = Rng::seed_from_u64(7);
    for len in [0usize, 1, 31, 1024, 1025, 9000, 70_000] {
        let mut ds = Vec::with_capacity(len);
        let mut w = Vec::with_capacity(len);
        for _ in 0..len {
            ds.push(if rng.gen_f64() < 0.1 {
                INF
            } else {
                rng.gen_range_u32(0, 1 << 30)
            });
            w.push(rng.gen_range_u32(0, 1000));
        }
        let a = native.candidates(&ds, &w).unwrap();
        let b = xla.candidates(&ds, &w).unwrap();
        assert_eq!(a, b, "parity broke at batch len {len}");
    }
}

#[test]
fn xla_pads_and_chunks_across_batch_sizes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaRelaxer::load(&dir).unwrap();
    // 200k entries forces chunking at the largest artifact batch (65536).
    let n = 200_000;
    let ds: Vec<u32> = (0..n).map(|i| i as u32 % 1_000_003).collect();
    let w: Vec<u32> = (0..n).map(|i| (i as u32 * 7) % 100).collect();
    let got = xla.candidates(&ds, &w).unwrap();
    let want = NativeRelaxer.candidates(&ds, &w).unwrap();
    assert_eq!(got, want);
    assert!(xla.executions >= 4, "expected multiple chunked executions");
}

#[test]
fn full_runs_identical_across_backends() {
    let Some(dir) = artifacts_dir() else { return };
    let graphs = vec![
        Arc::new(rmat(10, 8 << 10, RmatParams::default(), 3).unwrap()),
        Arc::new(road_grid(24, 24, 100, 9).unwrap()),
        Arc::new(erdos_renyi(512, 2048, 50, 4).unwrap()),
    ];
    for g in &graphs {
        for algo in [AlgoKind::Bfs, AlgoKind::Sssp] {
            for strategy in StrategyKind::ALL {
                let native = run(
                    g,
                    &RunConfig {
                        algo,
                        strategy,
                        ..Default::default()
                    },
                )
                .unwrap();
                let xla = run(
                    g,
                    &RunConfig {
                        algo,
                        strategy,
                        backend: Backend::Xla {
                            dir: Some(dir.clone()),
                        },
                        ..Default::default()
                    },
                )
                .unwrap();
                assert_eq!(native.dist, xla.dist, "{strategy}/{algo:?}: dist diverged");
                assert_eq!(
                    native.metrics.total_cycles(),
                    xla.metrics.total_cycles(),
                    "{strategy}/{algo:?}: simulated timing must be backend-independent"
                );
                assert_eq!(native.metrics.updates, xla.metrics.updates);
                assert_eq!(native.metrics.iterations, xla.metrics.iterations);
            }
        }
    }
}
