#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file exported by `--trace-out`.

Usage:
    tools/check_trace.py TRACE.json [--expect-shards N]

Checks the schema contract the telemetry layer promises (and that Perfetto
/ chrome://tracing silently depend on):

  - top level: {"displayTimeUnit": "ms", "traceEvents": [...]} , non-empty
  - every event has integer pid/tid, a ph in {M, X, C, i}, and (except
    metadata) a numeric non-negative ts
  - complete slices (X) carry a numeric dur >= 0
  - counters (C) and instants (i) carry an args object; instants have a
    scope s
  - exactly one process_name metadata record, at least one shard thread
    (thread_name matching "shard <i> [...]"), and the admission/scheduler
    thread on tid 0
  - with --expect-shards N: exactly N shard threads, numbered 0..N-1
  - at least one queue-depth counter sample when the trace came from the
    scheduler path (detected by the admission thread having any events)

Exit 0 on a valid trace, 1 with a findings list otherwise.
"""

import json
import re
import sys

VALID_PH = {"M", "X", "C", "i"}


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    path = sys.argv[1]
    expect_shards = None
    if "--expect-shards" in sys.argv:
        expect_shards = int(sys.argv[sys.argv.index("--expect-shards") + 1])

    with open(path) as f:
        doc = json.load(f)

    findings = []
    if doc.get("displayTimeUnit") != "ms":
        findings.append("displayTimeUnit must be 'ms'")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        print(f"check_trace FAILED: {path}: traceEvents missing or empty")
        return 1

    shard_threads = {}
    process_names = 0
    admission_tid0 = False
    queue_depth_samples = 0
    scheduler_events = 0

    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        ph = ev.get("ph")
        if ph not in VALID_PH:
            findings.append(f"{where}: bad ph {ph!r}")
            continue
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                findings.append(f"{where}: {key} must be an integer")
        if ph == "M":
            name = ev.get("name")
            value = ev.get("args", {}).get("name", "")
            if name == "process_name":
                process_names += 1
            elif name == "thread_name":
                m = re.match(r"shard (\d+) \[", value)
                if m:
                    shard_threads[int(m.group(1))] = ev.get("tid")
                elif value == "admission/scheduler":
                    admission_tid0 = ev.get("tid") == 0
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            findings.append(f"{where}: ts must be a non-negative number, got {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                findings.append(f"{where}: X slice needs dur >= 0, got {dur!r}")
        if ph in ("C", "i") and not isinstance(ev.get("args"), dict):
            findings.append(f"{where}: {ph} event needs an args object")
        if ph == "i" and not ev.get("s"):
            findings.append(f"{where}: instant needs a scope 's'")
        if ph == "C" and ev.get("name") == "queue depth":
            queue_depth_samples += 1
        if ev.get("tid") == 0:
            scheduler_events += 1

    if process_names != 1:
        findings.append(f"expected exactly one process_name record, got {process_names}")
    if not admission_tid0:
        findings.append("missing admission/scheduler thread_name on tid 0")
    if not shard_threads:
        findings.append("no shard thread tracks (thread_name 'shard <i> [...]')")
    if expect_shards is not None:
        want = set(range(expect_shards))
        if set(shard_threads) != want:
            findings.append(
                f"expected shard threads {sorted(want)}, got {sorted(shard_threads)}"
            )
    if scheduler_events and not queue_depth_samples:
        findings.append("scheduler-path trace has no queue-depth counter samples")

    if findings:
        print(f"check_trace FAILED: {path}:")
        for f_ in findings:
            print(f"  - {f_}")
        return 1
    print(
        f"check_trace OK: {path}: {len(events)} events, "
        f"{len(shard_threads)} shard track(s), "
        f"{queue_depth_samples} queue-depth sample(s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
