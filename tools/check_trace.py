#!/usr/bin/env python3
"""Validate telemetry artifacts exported by `--trace-out` / `--profile-out`,
and the `--json` schedule report.

Usage:
    tools/check_trace.py TRACE.json [--expect-shards N] [--profile PROFILE.json]
                                    [--report REPORT.json]
    tools/check_trace.py --profile PROFILE.json
    tools/check_trace.py --report REPORT.json

Trace checks (the schema contract the telemetry layer promises, and that
Perfetto / chrome://tracing silently depend on):

  - top level: {"displayTimeUnit": "ms", "traceEvents": [...]} , non-empty
  - every event has integer pid/tid, a ph in {M, X, C, i}, and (except
    metadata) a numeric non-negative ts
  - complete slices (X) carry a numeric dur >= 0
  - counters (C) and instants (i) carry an args object; instants have a
    scope s
  - exactly one process_name metadata record, at least one shard thread
    (thread_name matching "shard <i> [...]"), and the admission/scheduler
    thread on tid 0
  - with --expect-shards N: exactly N shard threads, numbered 0..N-1
  - at least one queue-depth counter sample when the trace came from the
    scheduler path (detected by the admission thread having any events)
  - profiled kernel slices (args carrying "warps") also carry consistent
    imbalance args: imbalance >= 1, cv >= 0, 0 <= occupancy <= 1, and
    max_warp_cycles >= mean_warp_cycles
  - fault-injection instants ("fault-inject" / "shard-down" / "shard-up" /
    "retry" / "requeue" / "deadline-expired") carry their payload args,
    and every shard-up follows at least one shard-down

Report checks (--report, the `--json` ScheduleReport):

  - the conservation identity holds exactly:
    arrived == served + dropped + deadline_expired + failed
  - arrived == admitted + dropped (admission-side ledger)
  - retries <= requeued (a retry is a re-admission of a requeued attempt)
  - every shard has downtime_ms >= 0 and availability in [0, 1]

Profile checks (--profile, the `lonestar-profile-v1` report):

  - schema tag, kernel_count/span_count/batch_count match the array lengths
  - every kernel aggregate has launches >= 1, mean_imbalance >= 1 and
    peak_imbalance >= mean's floor, 0 <= mean_occupancy <= 1
  - every span decomposition is conservative:
    queue_wait_ps + placement_stall_ps + compute_ps == latency_ps
  - every batch window has done_ps >= launch_ps and width >= 1

Exit 0 when everything passes, 1 with a findings list otherwise.
"""

import json
import re
import sys

VALID_PH = {"M", "X", "C", "i"}
EPS = 1e-9

# Fault-injection instants and the payload args each must carry (a subset
# match: exporters may add args, never drop these).
FAULT_INSTANT_ARGS = {
    "fault-inject": {"code", "param"},
    "shard-down": {"permanent"},
    "shard-up": {"outage_ms"},
    "retry": {"attempt"},
    "requeue": {"attempts"},
    "deadline-expired": {"deadline_ms"},
}

PROFILE_KERNEL_KEYS = {
    "shard", "kernel", "launches", "total_ps", "items", "warps",
    "mem_transactions", "mem_tx_per_item", "tail_excess_cycles",
    "imbalance_overhead_ps", "mean_imbalance", "peak_imbalance",
    "mean_cv", "mean_occupancy",
}
PROFILE_SPAN_KEYS = {
    "query", "shard", "arrival_ps", "admit_ps", "place_ps", "launch_ps",
    "done_ps", "latency_ps", "queue_wait_ps", "placement_stall_ps",
    "compute_ps", "imbalance_overhead_ps",
}
PROFILE_BATCH_KEYS = {
    "shard", "launch_ps", "done_ps", "width", "kernels", "kernel_ps",
    "imbalance_overhead_ps", "peak_imbalance", "critical_kernel",
    "critical_kernel_ps",
}


def check_kernel_args(where, args, findings):
    """Imbalance args on a profiled kernel slice."""
    imb = args.get("imbalance")
    if not isinstance(imb, (int, float)) or imb < 1 - EPS:
        findings.append(f"{where}: imbalance must be >= 1, got {imb!r}")
    cv = args.get("cv")
    if not isinstance(cv, (int, float)) or cv < 0:
        findings.append(f"{where}: cv must be >= 0, got {cv!r}")
    occ = args.get("occupancy")
    if not isinstance(occ, (int, float)) or not (0 <= occ <= 1 + EPS):
        findings.append(f"{where}: occupancy must be in [0, 1], got {occ!r}")
    max_c = args.get("max_warp_cycles", 0)
    mean_c = args.get("mean_warp_cycles", 0)
    if max_c + EPS < mean_c:
        findings.append(
            f"{where}: max_warp_cycles {max_c} < mean_warp_cycles {mean_c}"
        )


def check_trace(path):
    with open(path) as f:
        doc = json.load(f)

    findings = []
    if doc.get("displayTimeUnit") != "ms":
        findings.append("displayTimeUnit must be 'ms'")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"], ""

    shard_threads = {}
    process_names = 0
    admission_tid0 = False
    queue_depth_samples = 0
    scheduler_events = 0
    profiled_kernels = 0
    fault_instants = {name: 0 for name in FAULT_INSTANT_ARGS}

    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        ph = ev.get("ph")
        if ph not in VALID_PH:
            findings.append(f"{where}: bad ph {ph!r}")
            continue
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                findings.append(f"{where}: {key} must be an integer")
        if ph == "M":
            name = ev.get("name")
            value = ev.get("args", {}).get("name", "")
            if name == "process_name":
                process_names += 1
            elif name == "thread_name":
                m = re.match(r"shard (\d+) \[", value)
                if m:
                    shard_threads[int(m.group(1))] = ev.get("tid")
                elif value == "admission/scheduler":
                    admission_tid0 = ev.get("tid") == 0
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            findings.append(f"{where}: ts must be a non-negative number, got {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                findings.append(f"{where}: X slice needs dur >= 0, got {dur!r}")
            args = ev.get("args", {})
            if isinstance(args, dict) and "warps" in args:
                profiled_kernels += 1
                check_kernel_args(where, args, findings)
        if ph in ("C", "i") and not isinstance(ev.get("args"), dict):
            findings.append(f"{where}: {ph} event needs an args object")
        if ph == "i" and not ev.get("s"):
            findings.append(f"{where}: instant needs a scope 's'")
        if ph == "i" and ev.get("name") in FAULT_INSTANT_ARGS:
            name = ev["name"]
            fault_instants[name] += 1
            want = FAULT_INSTANT_ARGS[name]
            have = set(ev.get("args") or {})
            if not want <= have:
                findings.append(
                    f"{where}: {name} instant missing args {sorted(want - have)}"
                )
        if ph == "C" and ev.get("name") == "queue depth":
            queue_depth_samples += 1
        if ev.get("tid") == 0:
            scheduler_events += 1

    if process_names != 1:
        findings.append(f"expected exactly one process_name record, got {process_names}")
    if not admission_tid0:
        findings.append("missing admission/scheduler thread_name on tid 0")
    if not shard_threads:
        findings.append("no shard thread tracks (thread_name 'shard <i> [...]')")
    if EXPECT_SHARDS is not None:
        want = set(range(EXPECT_SHARDS))
        if set(shard_threads) != want:
            findings.append(
                f"expected shard threads {sorted(want)}, got {sorted(shard_threads)}"
            )
    if scheduler_events and not queue_depth_samples:
        findings.append("scheduler-path trace has no queue-depth counter samples")
    if fault_instants["shard-up"] and not fault_instants["shard-down"]:
        findings.append("shard-up instant(s) without any preceding shard-down")

    n_fault = sum(fault_instants.values())
    summary = (
        f"{len(events)} events, {len(shard_threads)} shard track(s), "
        f"{queue_depth_samples} queue-depth sample(s), "
        f"{profiled_kernels} profiled kernel slice(s), "
        f"{n_fault} fault/recovery instant(s)"
    )
    return findings, summary


def check_profile(path):
    with open(path) as f:
        doc = json.load(f)

    findings = []
    if doc.get("schema") != "lonestar-profile-v1":
        findings.append(f"schema must be 'lonestar-profile-v1', got {doc.get('schema')!r}")
    for count_key, arr_key in (
        ("kernel_count", None),  # kernel_count counts launches, not aggregates
        ("span_count", "spans"),
        ("batch_count", "batches"),
    ):
        n = doc.get(count_key)
        if not isinstance(n, int) or n < 0:
            findings.append(f"{count_key} must be a non-negative integer, got {n!r}")
        elif arr_key is not None and n != len(doc.get(arr_key, [])):
            findings.append(
                f"{count_key} = {n} but len({arr_key}) = {len(doc.get(arr_key, []))}"
            )
    for arr_key in ("kernels", "spans", "batches"):
        if not isinstance(doc.get(arr_key), list):
            findings.append(f"{arr_key} must be an array")

    for i, k in enumerate(doc.get("kernels") or []):
        where = f"kernels[{i}]"
        missing = PROFILE_KERNEL_KEYS - set(k)
        if missing:
            findings.append(f"{where}: missing keys {sorted(missing)}")
            continue
        if k["launches"] < 1:
            findings.append(f"{where}: launches must be >= 1")
        if k["mean_imbalance"] < 1 - EPS:
            findings.append(f"{where}: mean_imbalance {k['mean_imbalance']} < 1")
        if k["peak_imbalance"] + EPS < k["mean_imbalance"] and k["launches"] > 1:
            # peak is a max over the same population the mean averages
            findings.append(
                f"{where}: peak_imbalance {k['peak_imbalance']} < mean {k['mean_imbalance']}"
            )
        if not (0 <= k["mean_occupancy"] <= 1 + EPS):
            findings.append(f"{where}: mean_occupancy {k['mean_occupancy']} not in [0, 1]")

    for i, s in enumerate(doc.get("spans") or []):
        where = f"spans[{i}]"
        missing = PROFILE_SPAN_KEYS - set(s)
        if missing:
            findings.append(f"{where}: missing keys {sorted(missing)}")
            continue
        total = s["queue_wait_ps"] + s["placement_stall_ps"] + s["compute_ps"]
        if total != s["latency_ps"]:
            findings.append(
                f"{where}: decomposition {total} != latency_ps {s['latency_ps']} "
                "(must telescope exactly)"
            )
        if not (
            s["arrival_ps"] <= s["admit_ps"] <= s["place_ps"]
            <= s["launch_ps"] <= s["done_ps"]
        ):
            findings.append(f"{where}: lifecycle timestamps out of order")
        if s["imbalance_overhead_ps"] > s["compute_ps"]:
            findings.append(
                f"{where}: imbalance_overhead_ps {s['imbalance_overhead_ps']} "
                f"exceeds compute_ps {s['compute_ps']}"
            )

    for i, b in enumerate(doc.get("batches") or []):
        where = f"batches[{i}]"
        missing = PROFILE_BATCH_KEYS - set(b)
        if missing:
            findings.append(f"{where}: missing keys {sorted(missing)}")
            continue
        if b["done_ps"] < b["launch_ps"]:
            findings.append(f"{where}: done_ps < launch_ps")
        if b["width"] < 1:
            findings.append(f"{where}: width must be >= 1")
        if b["peak_imbalance"] < 1 - EPS:
            findings.append(f"{where}: peak_imbalance {b['peak_imbalance']} < 1")

    summary = (
        f"{doc.get('kernel_count', 0)} kernel launch(es), "
        f"{len(doc.get('kernels') or [])} aggregate row(s), "
        f"{len(doc.get('spans') or [])} span(s), "
        f"{len(doc.get('batches') or [])} batch(es)"
    )
    return findings, summary


def load_report(path):
    """The report is `--json` stdout: either a bare JSON object or the one
    `{...}` line embedded in the human-readable serve transcript."""
    with open(path) as f:
        text = f.read()
    try:
        return json.loads(text)
    except ValueError:
        for line in reversed(text.splitlines()):
            line = line.strip()
            if line.startswith("{"):
                return json.loads(line)
        raise


def check_report(path):
    doc = load_report(path)

    findings = []
    counts = {}
    for key in ("arrived", "admitted", "dropped", "served", "deadline_expired",
                "failed", "requeued", "retries"):
        v = doc.get(key)
        if not isinstance(v, int) or v < 0:
            findings.append(f"{key} must be a non-negative integer, got {v!r}")
            v = 0
        counts[key] = v
    if findings:
        return findings, ""

    accounted = (
        counts["served"] + counts["dropped"]
        + counts["deadline_expired"] + counts["failed"]
    )
    if counts["arrived"] != accounted:
        findings.append(
            f"conservation violated: arrived {counts['arrived']} != "
            f"served {counts['served']} + dropped {counts['dropped']} + "
            f"deadline_expired {counts['deadline_expired']} + "
            f"failed {counts['failed']} (= {accounted})"
        )
    if counts["arrived"] != counts["admitted"] + counts["dropped"]:
        findings.append(
            f"admission ledger violated: arrived {counts['arrived']} != "
            f"admitted {counts['admitted']} + dropped {counts['dropped']}"
        )
    if counts["retries"] > counts["requeued"]:
        findings.append(
            f"retries {counts['retries']} exceeds requeued {counts['requeued']} "
            "(every retry re-admits a previously requeued attempt)"
        )
    for i, s in enumerate(doc.get("shards") or []):
        where = f"shards[{i}]"
        down = s.get("downtime_ms")
        if not isinstance(down, (int, float)) or down < 0:
            findings.append(f"{where}: downtime_ms must be >= 0, got {down!r}")
        avail = s.get("availability")
        if avail is not None and not (0 - EPS <= avail <= 1 + EPS):
            findings.append(f"{where}: availability {avail!r} not in [0, 1]")

    summary = (
        f"arrived {counts['arrived']} == served {counts['served']} + "
        f"dropped {counts['dropped']} + expired {counts['deadline_expired']} + "
        f"failed {counts['failed']}; {counts['requeued']} requeue(s), "
        f"{counts['retries']} retrie(s)"
    )
    return findings, summary


EXPECT_SHARDS = None


def main() -> int:
    argv = sys.argv[1:]
    if not argv:
        print(__doc__)
        return 2
    global EXPECT_SHARDS
    if "--expect-shards" in argv:
        i = argv.index("--expect-shards")
        EXPECT_SHARDS = int(argv[i + 1])
        del argv[i : i + 2]
    profile_path = None
    if "--profile" in argv:
        i = argv.index("--profile")
        profile_path = argv[i + 1]
        del argv[i : i + 2]
    report_path = None
    if "--report" in argv:
        i = argv.index("--report")
        report_path = argv[i + 1]
        del argv[i : i + 2]
    trace_path = argv[0] if argv else None

    status = 0
    for path, checker, kind in (
        (trace_path, check_trace, "trace"),
        (profile_path, check_profile, "profile"),
        (report_path, check_report, "report"),
    ):
        if path is None:
            continue
        findings, summary = checker(path)
        if findings:
            print(f"check_{kind} FAILED: {path}:")
            for f_ in findings:
                print(f"  - {f_}")
            status = 1
        else:
            print(f"check_{kind} OK: {path}: {summary}")
    if trace_path is None and profile_path is None and report_path is None:
        print(__doc__)
        return 2
    return status


if __name__ == "__main__":
    sys.exit(main())
