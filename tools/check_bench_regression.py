#!/usr/bin/env python3
"""Gate the perf trajectory: compare a freshly measured BENCH_hotpath.json
against the committed baseline and fail on a >tolerance regression.

Usage:
    tools/check_bench_regression.py BASELINE FRESH [TOLERANCE]

The gate compares the *ratio* metrics (pooled-vs-legacy speedups, the
serving amortization factor) — dimensionless numbers that survive hardware
changes, unlike raw nanoseconds. Raw per-case timings ride along in both
files for trajectory plots; pass STRICT_NS=1 in the environment to also
gate each case's mean_ns (only meaningful when baseline and CI run on the
same machine class).

A baseline marked {"bootstrap": true} (or with no suites) accepts any
fresh measurement and asks the committer to promote it — that is how the
first real baseline lands without fabricating numbers.
"""

import json
import os
import sys

# Ratio metrics every fresh measurement must carry, per suite. Checked even
# against a bootstrap baseline, so a bench refactor cannot silently stop
# emitting a gated number (the scheduler entry lands here with the
# admission-control PR).
REQUIRED_RATIOS = {
    "hotpath": [
        "flatten_micro_speedup",
        "iteration_overhead_speedup",
        "serving_merge_speedup",
    ],
    "serving": [
        "inspection_amortization",
        "scheduler_sim_qps",
        "scheduler_par_qps",
        "scheduler_faulted_qps",
    ],
}


def check_required(fresh) -> list:
    failures = []
    for suite, names in sorted(REQUIRED_RATIOS.items()):
        ratios = fresh.get("suites", {}).get(suite, {}).get("ratios", {})
        for name in names:
            if name not in ratios:
                failures.append(
                    f"{suite}:{name}: required ratio missing from the fresh run"
                )
    return failures


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    tolerance = float(sys.argv[3]) if len(sys.argv) > 3 else 0.25

    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)

    required_failures = check_required(fresh)
    if baseline.get("bootstrap") or not baseline.get("suites"):
        if required_failures:
            print("bench gate FAILED (bootstrap baseline, but the fresh run is incomplete):")
            for f_ in required_failures:
                print(f"  - {f_}")
            return 1
        print(
            "=" * 72 + "\n"
            "WARNING: the committed bench baseline is still the BOOTSTRAP\n"
            "placeholder — the perf regression gate is NOT armed. Every\n"
            "measurement passes until a real baseline is promoted:\n"
            f"    cp {fresh_path} {baseline_path}\n"
            "(run benches on a quiet machine, then commit the result)\n"
            + "=" * 72
        )
        return 0

    failures = required_failures
    for suite, sdata in sorted(baseline.get("suites", {}).items()):
        fresh_suite = fresh.get("suites", {}).get(suite)
        if fresh_suite is None:
            failures.append(f"{suite}: suite missing from the fresh run")
            continue
        for name, base_val in sorted(sdata.get("ratios", {}).items()):
            cur = fresh_suite.get("ratios", {}).get(name)
            if cur is None:
                failures.append(f"{suite}:{name}: ratio missing from the fresh run")
            elif cur < base_val * (1.0 - tolerance):
                failures.append(
                    f"{suite}:{name}: {cur:.3f} is >{tolerance:.0%} below "
                    f"the baseline {base_val:.3f}"
                )
            else:
                print(f"ok {suite}:{name}: {cur:.3f} (baseline {base_val:.3f})")
        if os.environ.get("STRICT_NS") == "1":
            base_cases = {c["name"]: c for c in sdata.get("cases", [])}
            for c in fresh_suite.get("cases", []):
                base = base_cases.get(c["name"])
                if base is None or base["mean_ns"] <= 0:
                    continue
                if c["mean_ns"] > base["mean_ns"] * (1.0 + tolerance):
                    failures.append(
                        f"{suite}:{c['name']}: {c['mean_ns']:.0f} ns is "
                        f">{tolerance:.0%} above the baseline "
                        f"{base['mean_ns']:.0f} ns"
                    )

    if failures:
        print("bench regression gate FAILED:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
