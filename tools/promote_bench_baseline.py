#!/usr/bin/env python3
"""Promote a freshly measured bench run to the committed baseline.

Usage:
    tools/promote_bench_baseline.py FRESH [BASELINE]

BASELINE defaults to BENCH_hotpath.json at the repo root (derived from
this script's location) — the file check_bench_regression.py gates
against. The script refuses to promote a measurement that the regression
gate itself would reject:

  - every REQUIRED_RATIOS entry (shared with check_bench_regression.py)
    must be present, finite and > 0
  - the fresh run must not itself be a bootstrap placeholder
  - every case needs a positive mean_ns (a zeroed timing means the bench
    harness was stubbed out, not measured)

On success it rewrites BASELINE with the fresh document minus any
"bootstrap" marker, normalized to sorted keys + trailing newline so the
diff the committer reviews is minimal and stable. Run the benches on a
quiet machine first; the promoted numbers become the bar every future PR
is measured against.
"""

import json
import math
import os
import sys

# The gate's required ratios — import from the sibling script so the two
# tools cannot drift apart.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from check_bench_regression import REQUIRED_RATIOS  # noqa: E402

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_hotpath.json",
)


def validate(fresh) -> list:
    failures = []
    if fresh.get("bootstrap"):
        failures.append("fresh run is itself a bootstrap placeholder")
    suites = fresh.get("suites")
    if not isinstance(suites, dict) or not suites:
        failures.append("fresh run has no suites")
        return failures
    for suite, names in sorted(REQUIRED_RATIOS.items()):
        sdata = suites.get(suite)
        if sdata is None:
            failures.append(f"{suite}: suite missing from the fresh run")
            continue
        ratios = sdata.get("ratios", {})
        for name in names:
            val = ratios.get(name)
            if not isinstance(val, (int, float)) or not math.isfinite(val) or val <= 0:
                failures.append(
                    f"{suite}:{name}: required ratio must be a positive finite "
                    f"number, got {val!r}"
                )
        for case in sdata.get("cases", []):
            if case.get("mean_ns", 0) <= 0:
                failures.append(
                    f"{suite}:{case.get('name', '?')}: mean_ns must be > 0 "
                    "(was this actually measured?)"
                )
    return failures


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    fresh_path = sys.argv[1]
    baseline_path = sys.argv[2] if len(sys.argv) > 2 else DEFAULT_BASELINE

    with open(fresh_path) as f:
        fresh = json.load(f)

    failures = validate(fresh)
    if failures:
        print(f"refusing to promote {fresh_path}:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1

    fresh.pop("bootstrap", None)
    with open(baseline_path, "w") as f:
        json.dump(fresh, f, indent=2, sort_keys=True)
        f.write("\n")
    ratio_count = sum(
        len(s.get("ratios", {})) for s in fresh.get("suites", {}).values()
    )
    print(
        f"promoted {fresh_path} -> {baseline_path} "
        f"({len(fresh['suites'])} suite(s), {ratio_count} gated ratio(s)); "
        "review the diff and commit it"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
